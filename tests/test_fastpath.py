"""The fast-path equivalence contract (ISSUE 2).

The pre-refactor event loop is kept verbatim in
``repro.serving.reference``; these tests prove that

* the refactored ``ScenarioRunner`` (streamed arrivals/ticks, indexed
  wake-ups),
* the struct-of-arrays ``FastSimRunner``, and
* the memoized solver at quantum 0

all produce *identical decision sequences, batch buckets and aggregate
results* on the same workloads — across the vertical (sponge), static,
and horizontal (FA2, cold starts) policy families.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.baselines import FA2Policy, SpongePolicy, StaticPolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.solver import (DEFAULT_B, DEFAULT_C, MemoizedSolver,
                               SolverTable, solve_bruteforce)
from repro.network.traces import synth_4g_trace
from repro.serving.api import ScenarioRunner, SimBackend
from repro.serving.fastpath import FastSimRunner
from repro.serving.reference import ReferenceRunner
from repro.serving.workload import WorkloadGenerator

PERF = yolov5s_like()


def _batch(seed=3, rps=20, duration=90, poisson=True):
    trace = synth_4g_trace(duration, seed=seed)
    wl = WorkloadGenerator(rps=rps, slo=1.0, size_kb=200,
                           poisson=poisson, seed=seed)
    return wl.generate_batch(trace)


def _policy(name, solver="bruteforce"):
    if name == "sponge":
        return SpongePolicy(SpongeScaler(PERF, solver=solver))
    if name == "fa2":
        return FA2Policy(PERF, slo=1.0, expected_rps=20)
    return StaticPolicy(PERF, cores=8)


def _sig(report):
    """Everything that must match across runners."""
    decisions = [(t, d.c, d.b, d.n, d.scale_up_delay, d.feasible)
                 for t, d in (report.decisions or [])]
    return (decisions, report.buckets, report.n_requests,
            report.n_violations, report.core_seconds, report.p50,
            report.p99, report.core_timeline)


def _run_reference(policy, reqs):
    r = ReferenceRunner(policy, SimBackend(PERF, DEFAULT_C, DEFAULT_B,
                                           c0=16))
    r.monitor.rate.prior_rps = 20
    return r.run(reqs)


@pytest.mark.parametrize("name", ["sponge", "fa2", "static"])
@pytest.mark.parametrize("seed", [3, 11])
def test_runner_matches_reference(name, seed):
    """Streamed-event ScenarioRunner == verbatim pre-refactor loop."""
    batch = _batch(seed=seed)
    ref = _run_reference(_policy(name), batch.to_requests())
    new = ScenarioRunner(_policy(name),
                         SimBackend(PERF, DEFAULT_C, DEFAULT_B, c0=16))
    new.monitor.rate.prior_rps = 20
    got = new.run(batch.to_requests())
    assert _sig(got) == _sig(ref)


@pytest.mark.parametrize("name", ["sponge", "fa2", "static"])
@pytest.mark.parametrize("seed", [3, 11])
def test_fastpath_matches_reference(name, seed):
    """Struct-of-arrays FastSimRunner == verbatim pre-refactor loop."""
    batch = _batch(seed=seed)
    ref = _run_reference(_policy(name), batch.to_requests())
    fast = FastSimRunner(_policy(name), PERF, DEFAULT_C, DEFAULT_B,
                         c0=16, prior_rps=20)
    got = fast.run(batch)
    assert _sig(got) == _sig(ref)


def test_memoized_solver_is_decision_identical_at_quantum_zero():
    """scaler(solver="memo", quanta=0) == scaler(solver="bruteforce")
    through the full control loop."""
    batch = _batch(seed=5)
    ref = _run_reference(_policy("sponge"), batch.to_requests())
    memo_pol = SpongePolicy(SpongeScaler(PERF, solver="memo"))
    fast = FastSimRunner(memo_pol, PERF, DEFAULT_C, DEFAULT_B,
                         c0=16, prior_rps=20)
    got = fast.run(batch)
    assert _sig(got) == _sig(ref)
    stats = memo_pol.scaler.solver_stats()
    assert stats["hits"] + stats["misses"] == len(got.decisions or [])


def test_fastpath_accepts_only_decide_policies():
    class OnTickOnly:
        def on_tick(self, now, sim):  # pragma: no cover
            pass

    with pytest.raises(TypeError):
        FastSimRunner(OnTickOnly(), PERF, DEFAULT_C, DEFAULT_B)


def test_request_batch_roundtrip():
    batch = _batch(seed=9)
    assert np.all(np.diff(batch.arrival) >= 0), "must be arrival-sorted"
    reqs = batch.to_requests()
    assert len(reqs) == len(batch)
    i = len(batch) // 2
    r = reqs[i]
    assert r.deadline == batch.deadline[i] and r.arrival == batch.arrival[i]
    head = batch.head(10)
    assert len(head) == 10
    assert np.array_equal(head.arrival, batch.arrival[:10])


# --------------------------------------------------------------------------
# solver-level properties
# --------------------------------------------------------------------------
budgets = st.lists(st.floats(0.05, 3.0), min_size=0, max_size=40)
lams = st.floats(0.0, 40.0)
waits = st.floats(0.0, 0.5)


@given(budgets, lams, waits)
@settings(deadline=None)
def test_table_solver_agrees_with_bruteforce(rem, lam, wait):
    """The precomputed-grid solver is Algorithm 1, vectorized."""
    tab = SolverTable(PERF)
    d1 = solve_bruteforce(rem, lam, PERF, initial_wait=wait)
    d2 = tab.solve(rem, lam, initial_wait=wait)
    assert (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)


@given(budgets, lams, waits)
@settings(deadline=None)
def test_quantized_memo_is_conservative(rem, lam, wait):
    """Quantization floors budgets and ceils λ/wait, so when the exact
    solver is feasible and the quantized one is too, the quantized
    allocation is at least as large (never an optimistic under-provision).
    """
    memo = MemoizedSolver(PERF, budget_quantum=0.02, lam_quantum=0.5)
    exact = solve_bruteforce(rem, lam, PERF, initial_wait=wait)
    q = memo.solve(rem, lam, initial_wait=wait)
    if exact.feasible and q.feasible:
        assert q.c >= exact.c
    if not exact.feasible:
        # exact infeasible => the tighter quantized problem is too
        assert not q.feasible


def test_table_solver_fuzz_without_hypothesis():
    """Seeded fuzz kept independent of hypothesis availability."""
    tab = SolverTable(PERF)
    rng = np.random.default_rng(0)
    for _ in range(300):
        n = int(rng.integers(0, 40))
        rem = np.sort(rng.uniform(0.0, 3.0, n))
        lam = float(rng.uniform(0, 40))
        iw = float(rng.uniform(0, 0.5))
        d1 = solve_bruteforce(rem, lam, PERF, initial_wait=iw)
        d2 = tab.solve(rem, lam, initial_wait=iw)
        assert (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)


def test_memo_cache_hits_on_repeated_states():
    memo = MemoizedSolver(PERF, budget_quantum=0.01, lam_quantum=0.5)
    for _ in range(5):
        memo.solve([0.5, 0.7, 0.9], 12.3, initial_wait=0.01)
    assert memo.misses == 1 and memo.hits == 4
    assert memo.hit_rate == pytest.approx(0.8)


# --------------------------------------------------------------------------
# CostModel fixed-work identity (ISSUE 3): the FixedWorkCostModel adapter
# must reproduce PerfModel decisions bit-identically through all three
# loops — the refactor's "provably decision-identical special case".
# --------------------------------------------------------------------------
from repro.core.cost_model import FixedWorkCostModel, as_cost_model

COST = FixedWorkCostModel(PERF)


def test_fixed_work_adapter_latency_floats_identical():
    import numpy as np
    bs, cs = np.arange(1, 17), np.arange(1, 17)
    bb, cc = np.meshgrid(bs, cs, indexing="ij")
    assert np.array_equal(COST.batch_latency(bb, cc), PERF.latency(bb, cc))
    assert np.array_equal(COST.latency(bb, cc), PERF.latency(bb, cc))
    assert np.array_equal(COST.throughput(bb, cc), PERF.throughput(bb, cc))
    assert np.array_equal(COST.prefill_latency(cc, bb),
                          PERF.latency(bb, cc))
    assert as_cost_model(PERF) == COST
    assert as_cost_model(COST) is COST


@pytest.mark.parametrize("solver", ["bruteforce", "memo"])
@pytest.mark.parametrize("seed", [3, 11])
def test_cost_model_adapter_identical_across_all_loops(solver, seed):
    """scaler(FixedWorkCostModel(perf)) == scaler(perf) through the
    reference loop, the streamed ScenarioRunner and the fast path."""
    batch = _batch(seed=seed)
    ref = _run_reference(_policy("sponge"), batch.to_requests())

    def cost_policy():
        return SpongePolicy(SpongeScaler(COST, solver=solver))

    ref_cost = _run_reference(cost_policy(), batch.to_requests())
    assert _sig(ref_cost) == _sig(ref)

    new = ScenarioRunner(cost_policy(),
                         SimBackend(COST, DEFAULT_C, DEFAULT_B, c0=16))
    new.monitor.rate.prior_rps = 20
    assert _sig(new.run(batch.to_requests())) == _sig(ref)

    fast = FastSimRunner(cost_policy(), COST, DEFAULT_C, DEFAULT_B,
                         c0=16, prior_rps=20)
    assert _sig(fast.run(batch)) == _sig(ref)


@given(st.integers(0, 2**16), st.floats(8.0, 30.0),
       st.integers(30, 70))
# deliberately pinned (each example is two full engine runs); cheap
# solver-level property tests leave max_examples to the hypothesis
# profile so the nightly deep sweep can raise it (tests/conftest.py)
@settings(max_examples=10, deadline=None)
def test_cost_model_identity_property(seed, rps, duration):
    """Hypothesis sweep of the adapter identity on the fast path: any
    workload, bit-identical decisions/buckets/core-seconds."""
    batch = _batch(seed=seed, rps=rps, duration=duration)
    a = FastSimRunner(_policy("sponge"), PERF, DEFAULT_C, DEFAULT_B,
                      c0=16, prior_rps=rps)
    b = FastSimRunner(SpongePolicy(SpongeScaler(COST)), COST,
                      DEFAULT_C, DEFAULT_B, c0=16, prior_rps=rps)
    assert _sig(a.run(batch)) == _sig(b.run(batch))
