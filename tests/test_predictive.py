"""Beyond-paper predictive scaling components."""
import numpy as np

from repro.core.perf_model import yolov5s_like
from repro.core.predictive import (HoltForecaster, PredictiveSpongeScaler,
                                   TelemetryPolicy)
from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request


def test_holt_tracks_level_and_trend():
    f = HoltForecaster(alpha=0.5, beta=0.3)
    for i in range(20):
        f.observe(0.1 + 0.01 * i)  # rising comm latency
    assert f.forecast(1.0) > f.level
    assert f.trend > 0


def test_predictive_scaler_tightens_budgets_on_rising_cl():
    perf = yolov5s_like()
    base = SpongeScaler(perf)
    pred = PredictiveSpongeScaler(perf)
    for i in range(20):
        pred.observe_comm_latency(0.05 + 0.03 * i)
    q = EDFQueue()
    for _ in range(10):
        q.push(Request.make(arrival=0.0, comm_latency=0.3, slo=1.0))
    d_base = base.decide(0.0, q, lam=20.0)
    q2 = EDFQueue()
    for _ in range(10):
        q2.push(Request.make(arrival=0.0, comm_latency=0.3, slo=1.0))
    d_pred = pred.decide(0.0, q2, lam=20.0)
    assert pred.forecast_increase() > 0
    assert d_pred.c >= d_base.c, "rising-cl forecast must not scale DOWN"


def test_telemetry_policy_injects_inflight_budgets():
    from repro.network.traces import BandwidthTrace
    perf = yolov5s_like()
    tr = BandwidthTrace(t=np.arange(10.0), mbps=np.full(10, 0.5))
    sc = SpongeScaler(perf)
    pol = TelemetryPolicy(sc, tr, size_kb=200, slo=1.0)

    from repro.serving.api import ScenarioRunner, SimBackend
    sim = ScenarioRunner(pol, SimBackend(perf, range(1, 17),
                                         range(1, 17), c0=4))
    sim.monitor.rate.prior_rps = 20
    pol.on_tick(0.0, sim)
    # 0.5 MB/s -> cl ~ 0.41 s -> ~8 in-flight requests injected; the solver
    # must provision for their shrunken budgets despite an empty queue
    assert len(sc.decisions) == 1
    d = sc.decisions[0][1]
    assert d.c > 1
