"""Beyond-paper predictive scaling components."""
import numpy as np

from repro.core.perf_model import yolov5s_like
from repro.core.predictive import (HoltForecaster, PredictiveSpongeScaler,
                                   TelemetryPolicy)
from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request


def test_holt_tracks_level_and_trend():
    f = HoltForecaster(alpha=0.5, beta=0.3)
    for i in range(20):
        f.observe(0.1 + 0.01 * i)  # rising comm latency
    assert f.forecast(1.0) > f.level
    assert f.trend > 0


def test_predictive_scaler_tightens_budgets_on_rising_cl():
    perf = yolov5s_like()
    base = SpongeScaler(perf)
    pred = PredictiveSpongeScaler(perf)
    for i in range(20):
        pred.observe_comm_latency(0.05 + 0.03 * i)
    q = EDFQueue()
    for _ in range(10):
        q.push(Request.make(arrival=0.0, comm_latency=0.3, slo=1.0))
    d_base = base.decide(0.0, q, lam=20.0)
    q2 = EDFQueue()
    for _ in range(10):
        q2.push(Request.make(arrival=0.0, comm_latency=0.3, slo=1.0))
    d_pred = pred.decide(0.0, q2, lam=20.0)
    assert pred.forecast_increase() > 0
    assert d_pred.c >= d_base.c, "rising-cl forecast must not scale DOWN"


def test_telemetry_policy_injects_inflight_budgets():
    from repro.network.traces import BandwidthTrace
    perf = yolov5s_like()
    tr = BandwidthTrace(t=np.arange(10.0), mbps=np.full(10, 0.5))
    sc = SpongeScaler(perf)
    pol = TelemetryPolicy(sc, tr, size_kb=200, slo=1.0)

    from repro.serving.api import ScenarioRunner, SimBackend
    sim = ScenarioRunner(pol, SimBackend(perf, range(1, 17),
                                         range(1, 17), c0=4))
    sim.monitor.rate.prior_rps = 20
    pol.on_tick(0.0, sim)
    # 0.5 MB/s -> cl ~ 0.41 s -> ~8 in-flight requests injected; the solver
    # must provision for their shrunken budgets despite an empty queue
    assert len(sc.decisions) == 1
    d = sc.decisions[0][1]
    assert d.c > 1


def test_predictive_feed_reads_live_snapshot_not_heap():
    """Regression: a deadline re-key leaves a stale duplicate in the raw
    heap and a cancel leaves a dead tuple — ``PredictivePolicy._feed``
    must observe each live request exactly once and never a cancelled
    one (it reads the live-entry snapshot, not ``_heap``)."""
    from repro.core.predictive import PredictivePolicy

    class _CountingScaler(PredictiveSpongeScaler):
        def __init__(self, perf):
            super().__init__(perf)
            self.fed = []

        def observe_comm_latency(self, cl):
            self.fed.append(cl)
            super().observe_comm_latency(cl)

    class _Sim:
        def __init__(self, queue, completed):
            self.queue = queue
            self.monitor = type("M", (), {"completed": completed})()

    q = EDFQueue()
    # kept holds the earliest deadline so the lazy ``_fix_top`` never
    # gets a chance to sweep the stale tuples buried beneath it
    kept = Request.make(arrival=0.0, comm_latency=0.11, slo=1.0)
    rekeyed = Request.make(arrival=2.0, comm_latency=0.22, slo=1.0)
    doomed = Request.make(arrival=5.0, comm_latency=0.33, slo=1.0)
    for r in (kept, rekeyed, doomed):
        q.push(r)
    # re-key: pushes a fresh heap tuple, the old one goes stale in place
    assert q.update_deadline(rekeyed.id, rekeyed.deadline + 0.5)
    # cancel: removes from _live but the heap tuple remains
    assert q.cancel(doomed.id) is doomed
    assert len(q._heap) > len(q)  # the bug's precondition: stale tuples

    pol = PredictivePolicy(_CountingScaler(yolov5s_like()))
    pol._feed(_Sim(q, completed=[]))
    assert sorted(pol.scaler.fed) == [0.11, 0.22]  # once each, no doomed
    pol._feed(_Sim(q, completed=[]))
    assert sorted(pol.scaler.fed) == [0.11, 0.22]  # _seen dedup holds
