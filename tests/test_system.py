"""End-to-end behaviour tests for the Sponge system: the live engine path
(real JAX inference behind the control plane) and substrate round-trips."""
import numpy as np
import pytest

import jax

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.perf_model import PerfModel
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request
from repro.data import make_batch, synthetic_batches
from repro.models import build_model
from repro.serving.engine import ServingEngine, build_llm_step_fns, pad_tokens
from repro.train.loop import train_loop
from repro.train.optimizer import OptConfig


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("smollm-135m", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    f = save_checkpoint(str(tmp_path), params, step=7, metadata={"x": 1})
    shape_tree = jax.eval_shape(lambda: m.init(jax.random.key(1)))
    restored, meta = restore_checkpoint(f, shape_tree)
    assert meta["step"] == 7 and meta["x"] == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic():
    cfg = get_config("smollm-135m", reduced=True)
    b1 = make_batch(cfg, 4, 32, 123)
    b2 = make_batch(cfg, 4, 32, 123)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert b1["labels"][0, -1] == -100
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


@pytest.mark.slow
def test_training_loss_decreases():
    cfg = get_config("smollm-135m", reduced=True)
    m = build_model(cfg)
    oc = OptConfig(lr=1e-3, warmup_steps=3, total_steps=25)
    state, hist = train_loop(m, synthetic_batches(cfg, 4, 32, 25), oc,
                             log_every=8)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


@pytest.mark.slow
def test_live_engine_serves_with_vertical_scaling():
    cfg = get_config("smollm-135m", reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    prompt = 16
    c_set, b_set = (1, 2, 4), (1, 2, 4)
    fns = build_llm_step_fns(m, params, c_set, b_set, prompt, gen_tokens=4)
    perf = PerfModel(gamma=0.05, eps=0.01, delta=0.01, eta=0.02)
    sc = SpongeScaler(perf, c_set=c_set, b_set=b_set,
                      adaptation_interval=0.25)
    eng = ServingEngine(fns, sc, pad_tokens, prior_rps=20)
    eng.warmup(np.ones(prompt, np.int32))
    rng = np.random.default_rng(0)
    arrivals = []
    for i in range(40):
        req = Request.make(arrival=i * 0.04, comm_latency=0.02, slo=5.0)
        arrivals.append((req, rng.integers(0, cfg.vocab_size,
                                           prompt).astype(np.int32)))
    res = eng.run_script(arrivals)
    assert res["n"] == 40
    assert res["violation_rate"] < 0.5
    assert len(eng.decision_log) >= 2
    # results are generated token sequences
    assert eng.results[0].result.shape == (4,)
