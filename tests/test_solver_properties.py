"""Property-based solver invariants (ISSUE 4 satellites).

Two families, both hypothesis-driven (with seeded fallbacks so the
module stays useful when hypothesis is absent — see ``tests/_hyp.py``):

1. **Conservative quantization** — ``TokenMemoizedSolver`` with positive
   quanta solves a *tighter* problem than the exact token Algorithm 1
   (budgets floored, tokens/λ/wait ceiled, TBT floored), so it may
   over-provision but can never admit a decision the exact constraint
   set rejects.  Checked three ways: exact-infeasible ⇒
   quantized-infeasible; a quantized-feasible ``(c, b)`` re-verifies as
   feasible against the *unquantized* inputs; and when both are feasible
   the quantized choice is never earlier in Algorithm 1's (c, b) search
   order (never an optimistic under-provision).
2. **Cost-surface monotonicity** — the l(b, c) families the solvers
   search are monotone: nondecreasing in work (batch size, prompt
   tokens, decode slots) and nonincreasing in cores, for both
   ``FixedWorkCostModel`` and ``TokenCostModel`` (any fitted instance
   with nonnegative coefficients).  Algorithm 1's early-exit order is
   only optimal because of these invariants.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.cost_model import FixedWorkCostModel, TokenCostModel
from repro.core.perf_model import yolov5s_like
from repro.core.solver import TokenMemoizedSolver, solve_token_bruteforce

PERF = yolov5s_like()
FIXED = FixedWorkCostModel(PERF)
COST = TokenCostModel.smollm_like()


# --------------------------------------------------------------------------
# 1) quantized token solver is never less conservative than Algorithm 1
# --------------------------------------------------------------------------
def _check_conservative(budgets, tokens, lam, wait, tbt):
    toks = tokens[:len(budgets)]
    budgets = budgets[:len(toks)]
    q = TokenMemoizedSolver(COST, budget_quantum=0.02, lam_quantum=0.5,
                            token_quantum=16)
    exact = solve_token_bruteforce(budgets, toks, lam, COST,
                                   initial_wait=wait, tbt_budget=tbt)
    quant = q.solve(budgets, toks, lam, initial_wait=wait, tbt_budget=tbt)
    if not exact.feasible:
        # the quantized problem is tighter: it cannot be feasible where
        # the exact one is not
        assert not quant.feasible
    if quant.feasible:
        # no SLO-infeasible decision admitted: the quantized (c, b) must
        # re-verify against the ORIGINAL (unquantized) inputs
        recheck = solve_token_bruteforce(budgets, toks, lam, COST,
                                         c_set=(quant.c,),
                                         b_set=(quant.b,),
                                         initial_wait=wait,
                                         tbt_budget=tbt)
        assert recheck.feasible, (quant.c, quant.b)
    if exact.feasible and quant.feasible:
        # never earlier in the (c, b) search order = never an optimistic
        # under-provision
        assert (quant.c, quant.b) >= (exact.c, exact.b)


tok_budgets = st.lists(st.floats(0.05, 3.0), min_size=0, max_size=24)
tok_counts = st.lists(st.integers(1, 512), min_size=24, max_size=24)
tok_lams = st.floats(0.0, 40.0)
tok_waits = st.floats(0.0, 0.5)
tok_tbts = st.one_of(st.just(float("inf")), st.floats(0.02, 0.5))


@given(tok_budgets, tok_counts, tok_lams, tok_waits, tok_tbts)
@settings(deadline=None)
def test_token_memo_quantization_is_conservative(budgets, tokens, lam,
                                                 wait, tbt):
    """TokenMemoizedSolver at quantum > 0 never admits a decision the
    exact token Algorithm 1 rejects."""
    _check_conservative(budgets, tokens, lam, wait, tbt)


def test_token_memo_conservative_seeded_fuzz():
    """The same invariant, seeded (runs without hypothesis)."""
    rng = np.random.default_rng(7)
    for _ in range(120):
        n = int(rng.integers(0, 24))
        budgets = list(rng.uniform(0.05, 3.0, n))
        tokens = list(rng.integers(1, 512, max(n, 1)))
        lam = float(rng.uniform(0, 40))
        wait = float(rng.uniform(0, 0.5))
        tbt = float("inf") if rng.uniform() < 0.4 else \
            float(rng.uniform(0.02, 0.5))
        _check_conservative(budgets, tokens, lam, wait, tbt)


def test_token_memo_exact_at_quantum_zero():
    """Quanta at 0 make the cache key the exact input: decisions are
    identical to the bruteforce token Algorithm 1."""
    memo = TokenMemoizedSolver(COST)
    rng = np.random.default_rng(3)
    for _ in range(60):
        n = int(rng.integers(0, 16))
        budgets = rng.uniform(0.05, 2.0, n)
        tokens = rng.integers(1, 256, n)
        lam = float(rng.uniform(0, 30))
        d1 = solve_token_bruteforce(budgets, tokens, lam, COST)
        d2 = memo.solve(budgets, tokens, lam)
        assert (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)


# --------------------------------------------------------------------------
# 2) l(b, c) monotonicity invariants
# --------------------------------------------------------------------------
def _assert_monotone_grid(fn, rows_increase: bool = True):
    """fn(work, cores) over the (1..16, 1..16) grid: nondecreasing along
    work, nonincreasing along cores."""
    work = np.arange(1, 17, dtype=np.float64)
    cores = np.arange(1, 17, dtype=np.float64)
    ww, cc = np.meshgrid(work, cores, indexing="ij")
    lat = np.asarray(fn(ww, cc), np.float64)
    assert np.all(np.diff(lat, axis=0) >= -1e-12), "not monotone in work"
    assert np.all(np.diff(lat, axis=1) <= 1e-12), "not monotone in cores"


@pytest.mark.parametrize("model,label", [
    (PERF, "perf"), (FIXED, "fixed-work"), (COST, "token-full-service")])
def test_latency_monotone_in_b_and_c(model, label):
    _assert_monotone_grid(lambda b, c: model.latency(b, c))


def test_token_surfaces_monotone():
    _assert_monotone_grid(lambda t, c: COST.prefill_latency(c, t))
    _assert_monotone_grid(lambda s, c: COST.decode_latency(c, s))
    fw = FIXED
    _assert_monotone_grid(lambda t, c: fw.prefill_latency(c, t))


coeffs = st.floats(0.0, 0.1)


@given(coeffs, coeffs, coeffs, coeffs, coeffs, coeffs)
@settings(deadline=None)
def test_any_nonneg_token_model_is_monotone(gp, dp, gd, dd, eps, eta):
    """Every TokenCostModel with nonnegative coefficients (what ``fit``
    clamps to) satisfies the monotonicity the solvers rely on."""
    m = TokenCostModel(gamma_p=gp, delta_p=dp, gamma_d=gd, delta_d=dd,
                       eps=eps, eta=eta, mean_prompt=32.0, mean_decode=8.0)
    _assert_monotone_grid(lambda t, c: m.prefill_latency(c, t))
    _assert_monotone_grid(lambda s, c: m.decode_latency(c, s))
    _assert_monotone_grid(lambda b, c: m.latency(b, c))


def test_throughput_monotone_in_c():
    """h(b, c) = b / l(b, c): more cores never reduce throughput."""
    for model in (PERF, FIXED, COST):
        b = np.arange(1, 17, dtype=np.float64)[:, None]
        c = np.arange(1, 17, dtype=np.float64)[None, :]
        thr = np.asarray(model.throughput(b, c), np.float64)
        assert np.all(np.diff(thr, axis=1) >= -1e-12)
