"""Model-size degradation (ISSUE 9): ladder, (m, n, c, b) solver, fleet.

The acceptance contracts pinned here:

* **Pinned-m reduction** — ``MultiModelSolverTable`` with a single
  admissible rung is bit-identical to the PR 4 ``JointSolverTable`` on
  that rung (``solver_iters`` included: it is a pure delegation, not a
  re-derivation).
* **Monotone shed** — a feasible (m, n, c, b) decision sheds accuracy
  only when every strictly higher-accuracy admissible rung has no
  feasible (n, c, b); the floor fences rungs out of the search.
* **Swap accounting** — the weights-load penalty delays dispatch
  (busy_until) but never inflates core-second accounting, in both
  fleet engines, and in-flight work drains before the swap lands.
* **Engine identity** — ``FleetFastSimRunner`` == ``FleetExactRunner``
  decision-for-decision (model swaps included) on every
  degrade-under-pressure scenario.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.degradation import (DEFAULT_LADDER_ARCHS, FULL_LADDER_ARCHS,
                                    ModelLadder, ModelRung, default_ladder,
                                    resolve_ladder)
from repro.core.monitor import accuracy_weighted_goodput
from repro.core.perf_model import PerfModel, yolov5s_like
from repro.core.slo import Decision
from repro.core.solver import (DEFAULT_B, DEFAULT_C, JointSolverTable,
                               MultiModelMemoizedSolver,
                               MultiModelSolverTable, solve_joint_bruteforce,
                               solve_multimodel_bruteforce)
from repro.serving.fleet import (DegradingFleetScaler, FleetExactRunner,
                                 FleetFastSimRunner)
from repro.serving.scenarios import SCENARIOS, build_scenario, run_scenario

PERF = yolov5s_like()
N_SET = (1, 2, 4, 8, 16)
LADDER = default_ladder()
DEGRADE_SCENARIOS = ("degrade-sustained-overload", "degrade-flash-overload",
                     "degrade-fade-overload")


def _slowed(s: float) -> PerfModel:
    return PerfModel(gamma=PERF.gamma * s, eps=PERF.eps * s,
                     delta=PERF.delta * s, eta=PERF.eta * s)


def _two_rung_ladder(swap_big: float = 0.5, swap_small: float = 0.1
                     ) -> ModelLadder:
    """A synthetic big/small ladder with an 8x latency gap — wide enough
    to place budgets between the rungs deterministically."""
    return ModelLadder([
        ModelRung("big", 0.9, _slowed(8.0), swap_cost=swap_big),
        ModelRung("small", 0.6, PERF, swap_cost=swap_small)])


# --------------------------------------------------------------------------
# ladder construction + resolution
# --------------------------------------------------------------------------
def test_ladder_validates_and_orders():
    lad = _two_rung_ladder()
    assert [r.name for r in lad] == ["big", "small"]  # accuracy-descending
    assert lad.accuracy("big") == 0.9 and lad.swap_cost("small") == 0.1
    assert "big" in lad and "nope" not in lad
    with pytest.raises(KeyError):
        lad.rung("nope")
    with pytest.raises(ValueError):
        ModelLadder([])
    with pytest.raises(ValueError):                       # duplicate name
        ModelLadder([ModelRung("a", 0.9, PERF), ModelRung("a", 0.5, PERF)])
    with pytest.raises(ValueError):                       # duplicate accuracy
        ModelLadder([ModelRung("a", 0.9, PERF), ModelRung("b", 0.9, PERF)])
    with pytest.raises(ValueError):                       # accuracy range
        ModelLadder([ModelRung("a", 1.5, PERF)])


def test_ladder_floor_and_pins():
    lad = _two_rung_ladder()
    assert lad.best().name == "big"
    assert lad.best(0.95) if False else True
    with pytest.raises(ValueError):
        lad.best(0.95)                    # floor above every rung
    assert [r.name for r in lad.admissible(0.7)] == ["big"]
    assert [r.name for r in lad.admissible(0.0, m_set=("small",))] == \
        ["small"]
    with pytest.raises(ValueError):
        lad.admissible(0.7, m_set=("small",))   # pin below the floor


def test_resolve_ladder_specs():
    assert resolve_ladder(None) is None
    assert resolve_ladder(LADDER) is LADDER
    assert [r.name for r in resolve_ladder("default")] == \
        [r.name for r in default_ladder()]
    full = resolve_ladder("full")
    assert {r.name for r in full} == set(FULL_LADDER_ARCHS)
    two = resolve_ladder("smollm-135m, gemma-2b")
    assert {r.name for r in two} == {"smollm-135m", "gemma-2b"}
    seq = resolve_ladder(("smollm-135m", "smollm-360m"))
    assert {r.name for r in seq} == {"smollm-135m", "smollm-360m"}


def test_default_ladder_is_deterministic_and_swap_scaled():
    a, b = default_ladder(), default_ladder()
    for ra, rb in zip(a, b):
        assert ra.name == rb.name and ra.swap_cost == rb.swap_cost
        assert ra.cost.latency(4, 8) == rb.cost.latency(4, 8)
    # bigger total weights, longer load: gemma-2b dwarfs smollm-135m
    assert a.swap_cost("gemma-2b") > a.swap_cost("smollm-135m") > 0.0
    # larger active models are slower at every probed shape
    assert a.cost("gemma-2b").latency(1, 16) > \
        a.cost("smollm-135m").latency(1, 16)


# --------------------------------------------------------------------------
# the pinned-m reduction: bit-identity with the PR 4 joint solver
# --------------------------------------------------------------------------
def _decision_key(d: Decision):
    return (d.c, d.b, d.n, d.feasible, d.solver_iters)


def test_pinned_m_reduces_to_joint_solver():
    """m_set=(rung,) + current_m=rung is a pure delegation: every field
    of the PR 4 joint decision survives, solver_iters included."""
    mm = MultiModelSolverTable(LADDER, n_set=N_SET)
    rng = np.random.default_rng(0)
    for trial in range(40):
        rung = LADDER[trial % len(LADDER)]
        joint = JointSolverTable(rung.cost, n_set=N_SET)
        n = int(rng.integers(0, 30))
        rem = np.sort(rng.uniform(0.0, 2.5, n))
        lam = float(rng.uniform(0, 300))
        iw = float(rng.uniform(0, 0.4))
        d1 = joint.solve(rem, lam, initial_wait=iw)
        d2 = mm.solve(rem, lam, initial_wait=iw,
                      m_set=(rung.name,), current_m=rung.name)
        assert _decision_key(d1) == _decision_key(d2), (trial, rung.name)
        assert d2.m == rung.name
        # a floor that admits only this rung reduces identically too
        d3 = mm.solve(rem, lam, initial_wait=iw, m_set=(rung.name,),
                      current_m=rung.name,
                      accuracy_floor=rung.accuracy - 1e-9)
        assert _decision_key(d1) == _decision_key(d3)


def test_table_matches_bruteforce():
    """MultiModelSolverTable == solve_multimodel_bruteforce, fallback
    ordering included, across floors / pins / resident models."""
    mm = MultiModelSolverTable(LADDER, n_set=N_SET)
    names = [r.name for r in LADDER]
    rng = np.random.default_rng(1)
    for trial in range(60):
        n = int(rng.integers(0, 25))
        rem = np.sort(rng.uniform(0.0, 2.0, n))
        lam = float(rng.uniform(0, 400))
        iw = float(rng.uniform(0, 0.3))
        floor = float(rng.choice([0.0, 0.6, 0.65]))
        cur = names[int(rng.integers(0, len(names)))] \
            if rng.random() < 0.7 else None
        d1 = solve_multimodel_bruteforce(rem, lam, LADDER, n_set=N_SET,
                                         initial_wait=iw,
                                         accuracy_floor=floor,
                                         current_m=cur)
        d2 = mm.solve(rem, lam, initial_wait=iw, accuracy_floor=floor,
                      current_m=cur)
        assert (d1.m, d1.c, d1.b, d1.n, d1.feasible) == \
            (d2.m, d2.c, d2.b, d2.n, d2.feasible), trial


def test_memoized_matches_table_and_caches():
    memo = MultiModelMemoizedSolver(LADDER, n_set=N_SET)
    rem = np.array([0.5, 0.8, 1.2])
    d1 = memo.solve(rem, 40.0, accuracy_floor=0.6, current_m="gemma-2b")
    d2 = memo.solve(rem, 40.0, accuracy_floor=0.6, current_m="gemma-2b")
    assert (d1.m, d1.c, d1.b, d1.n) == (d2.m, d2.c, d2.b, d2.n)
    assert memo.hits >= 1
    # the resident model is part of the cache key, not folded away
    d3 = memo.solve(rem, 40.0, accuracy_floor=0.6,
                    current_m="smollm-360m")
    assert d3.m is not None


# --------------------------------------------------------------------------
# monotone shed + the accuracy floor
# --------------------------------------------------------------------------
@given(st.lists(st.floats(0.05, 2.0), min_size=0, max_size=25),
       st.floats(0.0, 400.0), st.floats(0.0, 0.3))
@settings(deadline=None, max_examples=40)
def test_feasible_decision_sheds_monotonically(rem, lam, iw):
    """The chosen rung of a *feasible* decision is the highest-accuracy
    admissible rung with any feasible (n, c, b): every strictly more
    accurate rung is infeasible under its own joint solve."""
    rem = sorted(rem)
    d = solve_multimodel_bruteforce(rem, lam, LADDER, n_set=N_SET,
                                    initial_wait=iw, accuracy_floor=0.6)
    if not d.feasible:
        return
    acc = LADDER.accuracy(d.m)
    assert acc >= 0.6 - 1e-12           # the floor fences the shed
    for rung in LADDER.admissible(0.6):
        dj = solve_joint_bruteforce(rem, lam, rung.cost, n_set=N_SET,
                                    initial_wait=iw)
        if rung.accuracy > acc:
            assert not dj.feasible, (rung.name, d.m)
        elif rung.name == d.m:
            assert dj.feasible


def test_feasible_decision_sheds_monotonically_seeded():
    """Deterministic fuzz twin of the hypothesis property above, so the
    monotone-shed contract is exercised even where hypothesis is
    absent."""
    rng = np.random.default_rng(9)
    checked = 0
    for _ in range(60):
        rem = np.sort(rng.uniform(0.05, 2.0, int(rng.integers(0, 25))))
        lam = float(rng.uniform(0, 200))
        iw = float(rng.uniform(0, 0.3))
        d = solve_multimodel_bruteforce(rem, lam, LADDER, n_set=N_SET,
                                        initial_wait=iw,
                                        accuracy_floor=0.6)
        if not d.feasible:
            continue
        checked += 1
        acc = LADDER.accuracy(d.m)
        assert acc >= 0.6 - 1e-12
        for rung in LADDER.admissible(0.6):
            if rung.accuracy > acc:
                dj = solve_joint_bruteforce(rem, lam, rung.cost,
                                            n_set=N_SET, initial_wait=iw)
                assert not dj.feasible, (rung.name, d.m)
    assert checked >= 10          # the fuzz actually hit feasible cases


def test_relaxed_budgets_never_shed():
    d = solve_multimodel_bruteforce([5.0, 6.0], 2.0, LADDER, n_set=N_SET)
    assert d.feasible and d.m == LADDER[0].name


def test_floor_above_ladder_raises():
    with pytest.raises(ValueError):
        solve_multimodel_bruteforce([], 1.0, LADDER, n_set=N_SET,
                                    accuracy_floor=0.99)


def test_swap_cost_gates_non_resident_rungs():
    """A lower rung that is feasible only without its weights-load time
    is NOT a legal shed target while non-resident: the solver must keep
    degrading (or fall back) rather than plan on weights it does not
    have."""
    lad = _two_rung_ladder(swap_big=0.5, swap_small=10.0)
    small_lat = float(PERF.latency(1, 16))
    big_lat = float(lad.cost("big").latency(1, 16))
    budget = (small_lat + big_lat) / 2.0      # small fits, big does not
    rem = np.full(3, budget)
    # resident on small: no swap charge, small is feasible
    d_res = solve_multimodel_bruteforce(rem, 1.0, lad, n_set=N_SET,
                                        current_m="small")
    assert d_res.feasible and d_res.m == "small"
    # resident on big: small costs 10 s of weights first — infeasible
    d_swap = solve_multimodel_bruteforce(rem, 1.0, lad, n_set=N_SET,
                                         current_m="big")
    assert not d_swap.feasible


def test_all_infeasible_fallback_prefers_sustaining_rung():
    """Dead backlog, λ above the top rung's ceiling: every rung predicts
    the same queued violations, and the capacity-accuracy product must
    hand the fallback to a rung that absorbs λ — not lock onto the top
    rung on raw accuracy (the sustained-overload regression)."""
    mm = MultiModelSolverTable(LADDER, n_set=N_SET)
    tops = {r.name: mm.tables[r.name].max_rate(None) for r in LADDER}
    rem = np.zeros(40)                       # every deadline already blown
    lam_mid = (tops["gemma-2b"] + tops["smollm-360m"]) / 2.0
    d = mm.solve(rem, lam_mid, accuracy_floor=0.6)
    assert not d.feasible
    assert tops[d.m] >= lam_mid, (d.m, tops)
    assert d.m != "gemma-2b"
    # ...and when λ is low enough for every rung to absorb, raw accuracy
    # decides again: the top rung wins the fallback
    d_low = mm.solve(rem, min(tops.values()) * 0.5, accuracy_floor=0.6)
    assert d_low.m == "gemma-2b"
    # bruteforce agrees on both fallback picks
    for lam in (lam_mid, min(tops.values()) * 0.5):
        db = solve_multimodel_bruteforce(rem, lam, LADDER, n_set=N_SET,
                                         accuracy_floor=0.6)
        dt = mm.solve(rem, lam, accuracy_floor=0.6)
        assert db.m == dt.m


# --------------------------------------------------------------------------
# accuracy-weighted goodput
# --------------------------------------------------------------------------
def test_accuracy_weighted_goodput_unit():
    # swap at t=5: requests finishing before it score 0.9, after 0.6
    log = [(0.0, "big", 0.9), (5.0, "small", 0.6)]
    finish = np.array([1.0, 6.0, 8.0, np.nan])
    deadline = np.array([2.0, 7.0, 7.5, 9.0])   # third one is late
    agp, macc = accuracy_weighted_goodput(finish, deadline, log, 10.0)
    assert agp == pytest.approx((0.9 + 0.6) / 10.0)
    # macc averages over *served* requests, late ones included
    assert macc == pytest.approx((0.9 + 0.6 + 0.6) / 3.0)
    agp0, macc0 = accuracy_weighted_goodput(
        np.array([np.nan]), np.array([1.0]), log, 10.0)
    assert agp0 == 0.0 and np.isnan(macc0)


# --------------------------------------------------------------------------
# scaler: asymmetric swap hysteresis
# --------------------------------------------------------------------------
def test_shed_commits_fast_recovery_commits_slow():
    lad = _two_rung_ladder()
    sc = DegradingFleetScaler(PERF, ladder=lad, adaptation_interval=1.0,
                              shed_patience=2, swap_patience=3,
                              scale_up_delay=0.0)
    assert sc.model == "big"
    overload = np.full(6, 0.4)      # big (~0.6 s single-item) cannot fit
    calm = np.empty(0)
    d = sc.decide_fleet(0.0, overload, 5.0, active_n=1)
    assert sc.model == "big" and d.m == "big"     # held: streak 1 < 2
    d = sc.decide_fleet(1.0, overload, 5.0, active_n=1)
    assert sc.model == "small" and d.m == "small"  # shed committed
    # recovery proposals must persist swap_patience=3 ticks
    d = sc.decide_fleet(2.0, calm, 5.0, active_n=1)
    assert sc.model == "small" and d.m == "small"
    d = sc.decide_fleet(3.0, calm, 5.0, active_n=1)
    assert sc.model == "small"
    d = sc.decide_fleet(4.0, calm, 5.0, active_n=1)
    assert sc.model == "big" and d.m == "big"      # recovery committed


def test_resident_proposal_resets_swap_streak():
    lad = _two_rung_ladder()
    sc = DegradingFleetScaler(PERF, ladder=lad, adaptation_interval=1.0,
                              shed_patience=2, swap_patience=3,
                              scale_up_delay=0.0)
    sc.decide_fleet(0.0, np.full(6, 0.4), 5.0, active_n=1)
    sc.decide_fleet(1.0, np.full(6, 0.4), 5.0, active_n=1)
    assert sc.model == "small"
    sc.decide_fleet(2.0, np.empty(0), 5.0, active_n=1)   # big, streak 1
    sc.decide_fleet(3.0, np.full(6, 0.4), 5.0, active_n=1)  # resident wins
    assert sc._swap_streak == 0 and sc.model == "small"
    sc.decide_fleet(4.0, np.empty(0), 5.0, active_n=1)   # streak restarts
    sc.decide_fleet(5.0, np.empty(0), 5.0, active_n=1)
    assert sc.model == "small"                           # 2 < 3: still held
    sc.decide_fleet(6.0, np.empty(0), 5.0, active_n=1)
    assert sc.model == "big"


def test_scaler_requires_ladder_and_validates_m0():
    with pytest.raises(ValueError):
        DegradingFleetScaler(PERF)
    with pytest.raises(KeyError):
        DegradingFleetScaler(PERF, ladder=_two_rung_ladder(), m0="nope")
    sc = DegradingFleetScaler(PERF, ladder=_two_rung_ladder(),
                              accuracy_floor=0.7)
    assert sc.model == "big"        # best rung above the floor


# --------------------------------------------------------------------------
# runners: drain-before-swap + core-second accounting (both engines)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("cls", (FleetFastSimRunner, FleetExactRunner))
def test_swap_penalty_delays_dispatch_not_core_seconds(cls):
    lad = _two_rung_ladder()

    def mk(with_ladder):
        sc = DegradingFleetScaler(PERF, ladder=lad,
                                  adaptation_interval=1.0)
        kw = dict(ladder=lad, m0="big") if with_ladder else {}
        return cls(sc, PERF, DEFAULT_C, DEFAULT_B, n0=2, c0=8, **kw)

    runner = mk(True)
    twin = mk(True)
    inflight = runner.replicas[0]
    inflight.busy_until = 12.5                 # old-model batch in flight
    runner._apply(Decision(c=8, b=2, n=2, m="small"), now=10.0)
    # drain-before-swap: the in-flight batch finishes first, THEN the
    # weights load; the idle replica pays the load from `now`
    assert inflight.busy_until == pytest.approx(12.5 + 0.1)
    assert runner.replicas[1].busy_until == pytest.approx(10.0 + 0.1)
    assert runner.model == "small"
    assert runner._lat == runner._lat_by_m["small"]
    assert runner.model_log == [(0.0, "big", 0.9), (10.0, "small", 0.6)]
    # swap penalties never inflate core-second accounting: the twin
    # applies the identical allocation without the swap and integrates
    # the same core-seconds to any later time
    twin.replicas[0].busy_until = 12.5
    twin._apply(Decision(c=8, b=2, n=2, m="big"), now=10.0)
    for r_sw, r_ns in zip(runner.replicas, twin.replicas):
        r_sw.account(50.0)
        r_ns.account(50.0)
        assert r_sw.core_seconds == pytest.approx(r_ns.core_seconds)
    assert twin.model_log == [(0.0, "big", 0.9)]   # no swap logged


@pytest.mark.parametrize("cls", (FleetFastSimRunner, FleetExactRunner))
def test_ladder_runner_validates_m0_and_cold_lat(cls):
    lad = _two_rung_ladder()
    sc = DegradingFleetScaler(PERF, ladder=lad, adaptation_interval=1.0)
    with pytest.raises(KeyError):
        cls(sc, PERF, DEFAULT_C, DEFAULT_B, n0=1, c0=8,
            ladder=lad, m0="nope")
    r = cls(sc, PERF, DEFAULT_C, DEFAULT_B, n0=1, c0=8, ladder=lad)
    assert r.model == "big"                    # policy's resident rung
    assert r._lat[(8, 2)] == pytest.approx(
        float(lad.cost("big").latency(2, 8)))


# --------------------------------------------------------------------------
# engine identity under model swaps (the ISSUE 9 oracle bar)
# --------------------------------------------------------------------------
def _sig(rep):
    decs = [(t, d.c, d.b, d.n, d.m, d.scale_up_delay, d.feasible)
            for t, d in (rep.decisions or [])]
    return (decs, rep.buckets, rep.n_requests, rep.n_violations,
            rep.core_seconds, rep.p50, rep.p99, rep.core_timeline,
            rep.accuracy_goodput, rep.mean_served_accuracy,
            rep.model_swaps, rep.model_timeline)


@pytest.mark.parametrize("name", DEGRADE_SCENARIOS)
def test_degrade_engine_identity_with_swaps(name):
    """Fast engine == exact gang loop on the degrade scenarios — model
    swaps, drain penalties, accuracy metrics and all."""
    batch, meta = build_scenario(name, duration=60, seed=3)
    ladder = resolve_ladder(meta["ladder"])

    def mk():
        return DegradingFleetScaler(
            PERF, adaptation_interval=meta["tick"],
            budget_quantum=0.01, lam_quantum=0.5, ladder=ladder,
            accuracy_floor=meta["accuracy_floor"])

    kw = dict(n0=meta["n0"], c0=meta["c0"], tick=meta["tick"],
              prior_rps=meta["expected_rps"], router=meta["router"])
    p1, p2 = mk(), mk()
    fast = FleetFastSimRunner(p1, PERF, DEFAULT_C, DEFAULT_B,
                              ladder=ladder, m0=p1.model, **kw)
    exact = FleetExactRunner(p2, PERF, DEFAULT_C, DEFAULT_B,
                             ladder=ladder, m0=p2.model, **kw)
    got = _sig(fast.run(batch, events=meta["fleet_events"]))
    ref = _sig(exact.run(batch, events=meta["fleet_events"]))
    assert got == ref
    assert got[10] > 0, "scenario exercised no model swap"


# --------------------------------------------------------------------------
# scenarios + run_scenario plumbing
# --------------------------------------------------------------------------
def test_degrade_scenarios_registered():
    for name in DEGRADE_SCENARIOS:
        assert name in SCENARIOS
        batch, meta = build_scenario(name, duration=60, seed=1)
        assert meta["fleet"] is True and len(batch) > 0
        assert meta["ladder"] == "default"
        assert meta["accuracy_floor"] == pytest.approx(0.60)


def test_run_scenario_rejects_ladder_on_non_fleet():
    with pytest.raises(ValueError, match="fleet scenarios only"):
        run_scenario("steady", duration=5, model_ladder="default")


def test_run_scenario_degradation_reporting():
    rep, stats = run_scenario("degrade-flash-overload", duration=45,
                              seed=3)
    assert stats["ladder"] == list(DEFAULT_LADDER_ARCHS[::-1]) or \
        set(stats["ladder"]) == set(DEFAULT_LADDER_ARCHS)
    assert stats["accuracy_floor"] == pytest.approx(0.60)
    assert rep.accuracy_goodput > 0.0
    assert 0.0 < rep.mean_served_accuracy <= 1.0
    assert rep.model_timeline[0][0] == 0.0
    # the floor fences smollm-135m out of the planner's reach
    assert all(m != "smollm-135m" for _, m, _ in rep.model_timeline)


def test_fixed_rung_policy_reports_accuracy():
    rep, stats = run_scenario("degrade-flash-overload", duration=45,
                              seed=3, policy="fixed-smollm-360m")
    assert rep.policy == "fixed-smollm-360m"
    assert rep.model_swaps == 0
    assert rep.mean_served_accuracy == pytest.approx(0.64)
    assert stats["ladder"] == ["smollm-360m"]
    with pytest.raises(KeyError):
        run_scenario("degrade-flash-overload", duration=5,
                     policy="fixed-no-such-arch")
