"""Determinism regression (ISSUE 4 satellite).

Same seed + same scenario must give bit-identical decision streams and
bucket logs (a) across the four fixed-work engines — the verbatim
pre-refactor ``ReferenceRunner``, the streamed ``ScenarioRunner``, the
struct-of-arrays ``FastSimRunner`` and the batched-tick
``VectorSimRunner`` (ISSUE 8) — and (b) across two consecutive
runs of every engine family (fixed-work, token, fleet).  This guards
the fleet refactor (and anything after it) against nondeterministic
dispatch sneaking into the control plane: any reliance on set/dict
iteration order, unseeded randomness or wall-clock time shows up here
as a diff between two identically configured runs.

The stochastic token engines (ISSUE 7: ``llm-heavy-tail`` /
``retrieve-then-generate`` with quantile admission and
cancel-on-overrun) are held to the same bar: all randomness is drawn
in the seeded scenario build, so equal seeds reproduce the decision
stream, reports, ``n_cancelled`` and predictor telemetry exactly on
both token engines.
"""
import numpy as np
import pytest

from repro.core.baselines import SpongePolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.api import ScenarioRunner, SimBackend
from repro.serving.fastpath import FastSimRunner
from repro.serving.reference import ReferenceRunner
from repro.serving.scenarios import build_scenario, run_scenario
from repro.serving.vectorpath import VectorSimRunner

PERF = yolov5s_like()
SEED = 11


def _decision_sig(report):
    return [(t, d.c, d.b, d.n, d.feasible)
            for t, d in (report.decisions or [])]


def _sig(report):
    return (_decision_sig(report), report.buckets, report.n_requests,
            report.n_violations, report.core_seconds)


def _fixed_engines(batch, meta):
    """Run the same scenario workload through all four fixed-work
    engines with identically configured sponge policies."""
    tick = meta.get("tick", 1.0)
    prior = meta["expected_rps"]

    def policy():
        return SpongePolicy(SpongeScaler(PERF, adaptation_interval=tick))

    ref = ReferenceRunner(policy(), SimBackend(PERF, DEFAULT_C, DEFAULT_B,
                                               c0=16), tick=tick)
    ref.monitor.rate.prior_rps = prior
    r_ref = ref.run(batch.to_requests())

    new = ScenarioRunner(policy(), SimBackend(PERF, DEFAULT_C, DEFAULT_B,
                                              c0=16), tick=tick)
    new.monitor.rate.prior_rps = prior
    r_new = new.run(batch.to_requests())

    fast = FastSimRunner(policy(), PERF, DEFAULT_C, DEFAULT_B, c0=16,
                         tick=tick, prior_rps=prior)
    r_fast = fast.run(batch)

    vec = VectorSimRunner(policy(), PERF, DEFAULT_C, DEFAULT_B, c0=16,
                          tick=tick, prior_rps=prior)
    r_vec = vec.run(batch)
    return r_ref, r_new, r_fast, r_vec


@pytest.mark.parametrize("name", ["steady", "mixed-slo"])
def test_same_seed_identical_across_engines(name):
    """reference == streamed == fastpath == vectorpath on the same
    scenario build."""
    batch, meta = build_scenario(name, duration=60, seed=SEED)
    r_ref, r_new, r_fast, r_vec = _fixed_engines(batch, meta)
    assert _sig(r_ref) == _sig(r_new) == _sig(r_fast) == _sig(r_vec)


def test_same_seed_identical_scenario_builds():
    """build_scenario is a pure function of (name, knobs, seed)."""
    a, _ = build_scenario("flash-crowd", duration=90, seed=SEED)
    b, _ = build_scenario("flash-crowd", duration=90, seed=SEED)
    for col in ("send", "arrival", "comm_latency", "deadline", "slo",
                "size_kb", "prompt_tokens", "decode_tokens", "tbt_slo"):
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    c, _ = build_scenario("flash-crowd", duration=90, seed=SEED + 1)
    assert not np.array_equal(a.arrival, c.arrival), \
        "different seeds must differ"


@pytest.mark.parametrize("name,engine", [
    ("steady", "fast"), ("steady", "exact"), ("steady", "vector"),
    ("mixed-slo", "fast"), ("mixed-slo", "vector"),
    ("llm-chat", "fast"), ("llm-chat", "exact"),
    ("replica-failure", "fast"), ("replica-failure", "exact"),
    ("fleet-flash-crowd", "fast"),
    ("mixed-zoo", "fast"), ("mixed-zoo", "exact"),
    ("mixed-zoo-rush", "fast"),
    ("llm-heavy-tail", "fast"), ("llm-heavy-tail", "exact"),
    ("retrieve-then-generate", "fast"),
])
def test_two_consecutive_runs_identical(name, engine):
    """Every engine family is run-to-run deterministic at equal seed:
    fixed-work, token (continuous batching), fleet (joint scaling) and
    the multi-tenant pool (marginal-value core swapping)."""
    kw = dict(engine=engine, duration=45, seed=SEED)
    r1, _ = run_scenario(name, **kw)
    r2, _ = run_scenario(name, **kw)
    assert _sig(r1) == _sig(r2)
    assert (r1.p50, r1.p99, r1.tokens_served) == \
        (r2.p50, r2.p99, r2.tokens_served)


def test_token_fast_engine_decision_determinism():
    """The token engine's full report (TTFT percentiles, TBT violation
    rate) is reproducible too — decode-stream bookkeeping included."""
    kw = dict(engine="fast", duration=40, seed=3)
    r1, s1 = run_scenario("llm-mixed-len", **kw)
    r2, s2 = run_scenario("llm-mixed-len", **kw)
    assert _sig(r1) == _sig(r2)
    assert r1.ttft_p99 == r2.ttft_p99
    assert r1.tbt_violation_rate == r2.tbt_violation_rate
    assert s1["events"] == s2["events"]


@pytest.mark.parametrize("engine", ["fast", "exact"])
def test_stochastic_engine_two_run_identity(engine):
    """ISSUE 7 satellite: the distribution-aware paths (quantile
    admission, speculative budgets, predictor feedback, overrun
    cancels) introduce no hidden nondeterminism — every RNG draw lives
    in the seeded scenario build, and a fresh UncertaintyConfig is
    built per run, so two equal-seed runs are bit-identical down to
    the cancel counts and predictor telemetry."""
    kw = dict(engine=engine, requests=1500, seed=SEED)
    r1, s1 = run_scenario("llm-heavy-tail", **kw)
    r2, s2 = run_scenario("llm-heavy-tail", **kw)
    assert _sig(r1) == _sig(r2)
    assert r1.n_cancelled == r2.n_cancelled > 0
    assert r1.ttft_p99 == r2.ttft_p99
    assert r1.tbt_violation_rate == r2.tbt_violation_rate
    u1, u2 = s1["uncertainty"], s2["uncertainty"]
    assert u1["overrun_cancels"] == u2["overrun_cancels"]
    assert u1["slack_factor"] == u2["slack_factor"]
    assert u1["calibration_error"] == u2["calibration_error"]
    r3, _ = run_scenario("llm-heavy-tail", engine=engine,
                         requests=1500, seed=SEED + 1)
    assert _sig(r3) != _sig(r1), "different seeds must diverge"
