"""Optional-hypothesis shim for the property-test modules.

With hypothesis installed, re-exports ``given``/``settings``/``st``
unchanged so property tests run at full strength.  Without it, each
``@given`` test body collapses to ``pytest.importorskip("hypothesis")``
(an individual skip), while the plain example-based tests in the same
module keep running — importing hypothesis at module top used to fail the
whole collection (the seed failure).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: any strategy call
        returns None (only ever passed to the stub ``given``)."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
