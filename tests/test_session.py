"""Online session API tests (ISSUE 5).

Three contracts:

1. **Replay equivalence** — a recorded transcript (submits only, no
   renegotiation) driven op by op through a session produces
   bit-identical decision/bucket streams to the batch ``run()`` path,
   on the exact, fast and fleet engines.
2. **Cross-engine renegotiation equivalence** — with mid-flight
   update/cancel streams applied, the exact object-based session and
   the struct-of-arrays fast session still produce identical decision
   streams and aggregates (solver quanta 0).
3. **The acceptance bar** — ``slo-renegotiation`` runs ≥100k requests
   through ``FastSimRunner`` via the session API, and tightening queued
   budgets measurably changes the solver's (c, b) decision stream vs
   the no-renegotiation replay of the same workload.
"""
import numpy as np
import pytest

from repro.core.baselines import FA2Policy, SpongePolicy, StaticPolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.network.traces import synth_4g_trace
from repro.serving.api import ScenarioRunner, SimBackend
from repro.serving.fastpath import FastSimRunner, TokenFastSimRunner
from repro.serving.fleet import FleetFastSimRunner, FleetSpongeScaler
from repro.serving.scenarios import build_scenario, run_scenario
from repro.serving.session import (SessionTranscript, drive_session_events,
                                   replay_transcript)
from repro.serving.workload import WorkloadGenerator

PERF = yolov5s_like()


def _batch(seed=3, rps=20, duration=60, poisson=True):
    trace = synth_4g_trace(duration, seed=seed)
    wl = WorkloadGenerator(rps=rps, slo=1.0, size_kb=200,
                           poisson=poisson, seed=seed)
    return wl.generate_batch(trace)


def _policy(name="sponge", solver="bruteforce"):
    if name == "sponge":
        return SpongePolicy(SpongeScaler(PERF, solver=solver))
    if name == "fa2":
        return FA2Policy(PERF, slo=1.0, expected_rps=20)
    return StaticPolicy(PERF, cores=8)


def _sig(report):
    decisions = [(t, d.c, d.b, d.n, d.scale_up_delay, d.feasible)
                 for t, d in (report.decisions or [])]
    return (decisions, report.buckets, report.n_requests,
            report.n_violations, report.core_seconds, report.p50,
            report.p99, report.core_timeline)


# --------------------------------------------------------------------------
# 1. replay-equivalence fixture: transcript == legacy run(), per engine
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["sponge", "fa2", "static"])
def test_transcript_replay_matches_batch_run_fast(name):
    batch = _batch(seed=11)
    ref = FastSimRunner(_policy(name), PERF, DEFAULT_C, DEFAULT_B,
                        c0=16, prior_rps=20).run(batch)
    sess = FastSimRunner(_policy(name), PERF, DEFAULT_C, DEFAULT_B,
                         c0=16, prior_rps=20).session()
    got = replay_transcript(sess, SessionTranscript.from_batch(batch),
                            batch)
    assert _sig(got) == _sig(ref)
    assert got.n_cancelled == 0


def test_transcript_replay_matches_batch_run_exact():
    batch = _batch(seed=7, duration=45)

    def runner():
        r = ScenarioRunner(_policy("sponge"),
                           SimBackend(PERF, DEFAULT_C, DEFAULT_B, c0=16))
        r.monitor.rate.prior_rps = 20
        return r

    ref = runner().run(batch.to_requests())
    got = replay_transcript(runner().session(),
                            SessionTranscript.from_batch(batch), batch)
    assert _sig(got) == _sig(ref)


def test_transcript_replay_matches_batch_run_fleet():
    batch, meta = build_scenario("replica-failure", duration=90, seed=5)
    events = meta["fleet_events"]

    def runner():
        pol = FleetSpongeScaler(PERF, c_set=DEFAULT_C, b_set=DEFAULT_B,
                                adaptation_interval=meta["tick"])
        return FleetFastSimRunner(pol, PERF, DEFAULT_C, DEFAULT_B,
                                  n0=meta["n0"], c0=meta["c0"],
                                  tick=meta["tick"],
                                  prior_rps=meta["expected_rps"])

    ref = runner().run(batch, events=events)
    sess = runner().session(fleet_events=events)
    got = replay_transcript(sess, SessionTranscript.from_batch(batch),
                            batch)
    assert _sig(got) == _sig(ref)


def test_transcript_replay_matches_batch_run_token():
    batch, meta = build_scenario("llm-chat", duration=40, seed=9)
    from repro.core.scaler import TokenSpongeScaler

    def runner():
        scaler = TokenSpongeScaler(meta["cost"], c_set=DEFAULT_C,
                                   b_set=DEFAULT_B,
                                   adaptation_interval=meta["tick"])
        return TokenFastSimRunner(scaler, meta["cost"], DEFAULT_C,
                                  DEFAULT_B, c0=16, tick=meta["tick"],
                                  prior_rps=meta["expected_rps"])

    ref = runner().run(batch)
    got = replay_transcript(runner().session(),
                            SessionTranscript.from_batch(batch), batch)
    assert _sig(got) == _sig(ref)
    assert got.tokens_served == ref.tokens_served
    assert got.ttft_p99 == ref.ttft_p99


# --------------------------------------------------------------------------
# 2. renegotiation equivalence + semantics across engines
# --------------------------------------------------------------------------
def test_exact_and_fast_sessions_agree_under_renegotiation():
    """With a live update/cancel stream applied, the object-based and
    struct-of-arrays sessions stay decision-identical (quanta 0)."""
    for name in ("slo-renegotiation", "cancel-storm"):
        fast, fstats = run_scenario(name, engine="fast", duration=50,
                                    seed=13, budget_quantum=0.0,
                                    lam_quantum=0.0)
        exact, estats = run_scenario(name, engine="exact", duration=50,
                                     seed=13)
        assert fstats["session"] == estats["session"], name
        d_f = [(t, d.c, d.b) for t, d in fast.decisions]
        d_e = [(t, d.c, d.b) for t, d in exact.decisions]
        assert d_f == d_e, name
        assert (fast.n_requests, fast.n_violations, fast.n_cancelled) \
            == (exact.n_requests, exact.n_violations, exact.n_cancelled)
        assert fast.buckets == exact.buckets, name


def _backlogged_session():
    """A static 8-core slot with a 6-deep arrival burst: the head
    dispatches immediately (b=1), the tail queues behind ~0.088 s
    service times — a deterministic window to renegotiate in."""
    runner = FastSimRunner(_policy("static"), PERF, (8,), (1, 2, 4, 8),
                           c0=8, tick=1.0)
    sess = runner.session()
    hs = [sess.submit(send=0.5, comm_latency=0.1, slo=5.0)
          for _ in range(6)]
    return sess, hs


def test_update_slo_changes_outcome_microcase():
    """One backlog, one fade: without renegotiation the run is clean;
    tightening a queued request's deadline below its feasible finish
    turns the same completion into a violation — proof the renegotiated
    deadline (not the submit-time one) is what accounting judges."""
    sess, hs = _backlogged_session()
    sess.step_until(0.7)
    tail = hs[-1]
    assert sess.record(tail)["status"] == "queued"
    assert sess.update_slo(tail, deadline=0.71)   # fade: near-past budget
    rep = sess.finish(30.0)
    assert rep.n_requests == 6 and rep.n_violations == 1
    rec = sess.record(tail)
    assert rec["status"] == "done" and rec["violated"] is True

    sess2, _ = _backlogged_session()
    rep2 = sess2.finish(30.0)
    assert rep2.n_requests == 6 and rep2.n_violations == 0


def test_relaxed_budget_avoids_violation():
    """The mirror case: a hopeless submit-time deadline relaxed while
    queued (network recovered) completes clean."""
    def run(relax):
        runner = FastSimRunner(_policy("static"), PERF, (8,),
                               (1, 2, 4, 8), c0=8, tick=1.0)
        sess = runner.session()
        hs = [sess.submit(send=0.5, comm_latency=0.1,
                          slo=5.0 if i < 5 else 0.25)
              for i in range(6)]
        sess.step_until(0.65)          # head in service until ~0.688
        if relax:
            assert sess.record(hs[-1])["status"] == "queued"
            assert sess.update_slo(hs[-1], slo=5.0)
        return sess.finish(30.0)

    assert run(relax=False).n_violations >= 1
    assert run(relax=True).n_violations == 0


def test_cancelled_requests_leave_every_aggregate():
    runner = FastSimRunner(_policy("sponge"), PERF, c0=16, tick=1.0)
    sess = runner.session()
    handles = [sess.submit(send=3.0 + 0.01 * i, comm_latency=0.2,
                           slo=8.0) for i in range(20)]
    pending_cancel = sess.cancel(handles[-1])  # cancel before arrival
    assert pending_cancel
    sess.step_until(3.3)
    cancelled = [h for h in handles[:10] if sess.cancel(h)]
    assert cancelled, "some requests must still be queued at t=3.3"
    assert not sess.cancel(cancelled[0])       # double-cancel
    assert not sess.update_slo(cancelled[0], slo=9.0)
    rep = sess.finish(40.0)
    assert rep.n_cancelled == len(cancelled) + 1
    assert rep.n_requests == 20 - rep.n_cancelled
    assert rep.n_violations == 0


def test_pending_cancel_counted_uniformly_across_engines():
    """Cancelling a submitted-but-not-yet-arrived request must land in
    n_cancelled on the object-based and column sessions alike."""
    fast = FastSimRunner(_policy("sponge"), PERF, c0=16).session()
    exact_runner = ScenarioRunner(_policy("sponge"),
                                  SimBackend(PERF, DEFAULT_C, DEFAULT_B,
                                             c0=16))
    exact = exact_runner.session()
    reports = []
    for sess in (fast, exact):
        hs = [sess.submit(send=2.0 + 0.1 * i, comm_latency=0.1, slo=8.0)
              for i in range(5)]
        assert sess.cancel(hs[3])          # before its arrival
        reports.append(sess.finish(30.0))
    for rep in reports:
        assert rep.n_cancelled == 1
        assert rep.n_requests == 4


def test_cancel_deflates_lambda_window():
    """A cancel storm must retract arrivals from the λ estimate."""
    runner = FastSimRunner(_policy("sponge"), PERF, c0=16, tick=1.0)
    sess = runner.session()
    hs = [sess.submit(send=1.0 + 0.001 * i, comm_latency=0.5, slo=30.0)
          for i in range(50)]
    sess.step_until(1.6)
    lam_before = sess._rate(1.6)
    n_ok = sum(sess.cancel(h) for h in hs[:40])
    assert n_ok > 0
    lam_after = sess._rate(1.6)
    assert lam_after < lam_before


def test_token_session_renegotiation_scope():
    """Token sessions renegotiate TTFT only while a request waits for
    admission; once the prompt joins a decode step it is committed."""
    batch, meta = build_scenario("llm-chat", duration=30, seed=21)
    from repro.core.scaler import TokenSpongeScaler
    scaler = TokenSpongeScaler(meta["cost"], c_set=DEFAULT_C,
                               b_set=DEFAULT_B,
                               adaptation_interval=meta["tick"])
    runner = TokenFastSimRunner(scaler, meta["cost"], DEFAULT_C,
                                DEFAULT_B, c0=16, tick=meta["tick"],
                                prior_rps=meta["expected_rps"])
    sess = runner.session()
    handles = sess.submit_batch(batch)
    t_mid = float(batch.arrival[len(batch) // 2])
    sess.step_until(t_mid)
    outcomes = {"applied": 0, "refused": 0}
    for h in handles:
        ok = sess.update_slo(h, deadline=float(batch.deadline[h]) + 0.2)
        outcomes["applied" if ok else "refused"] += 1
    assert outcomes["applied"] > 0 and outcomes["refused"] > 0
    rep = sess.finish()
    assert rep.tokens_served > 0 and rep.n_requests > 0


def test_fleet_session_tighten_reroutes_and_runs():
    """Tightening queued budgets on a fleet re-offers them to the router
    and the run still completes consistently (every request served or
    cancelled, none lost)."""
    batch, meta = build_scenario("fleet-flash-crowd", duration=60, seed=3)
    pol = FleetSpongeScaler(PERF, c_set=DEFAULT_C, b_set=DEFAULT_B,
                            adaptation_interval=meta["tick"])
    runner = FleetFastSimRunner(pol, PERF, DEFAULT_C, DEFAULT_B,
                                n0=meta["n0"], c0=meta["c0"],
                                tick=meta["tick"],
                                prior_rps=meta["expected_rps"],
                                router="edf-deadline")
    sess = runner.session()
    handles = sess.submit_batch(batch)
    rng = np.random.default_rng(0)
    pick = rng.choice(len(batch), size=len(batch) // 5, replace=False)
    events = sorted((float(batch.arrival[i]) + 0.05, "update", int(i),
                     float(batch.deadline[i]) - 0.3) for i in pick)
    applied = drive_session_events(sess, handles, events)
    assert applied["update"] > 0
    rep = sess.finish()
    assert rep.n_requests + rep.n_cancelled <= len(batch)
    assert rep.n_requests > 0
    # consistency: replica deadline mirrors drained along with queues
    for rep_ in runner.replicas:
        assert len(rep_.dls) == len(rep_.queue)


# --------------------------------------------------------------------------
# 3. the acceptance bar: >=100k requests, decision stream must move
# --------------------------------------------------------------------------
def test_slo_renegotiation_changes_decisions_at_scale():
    rep_ev, st_ev = run_scenario("slo-renegotiation", engine="fast",
                                 requests=110_000, seed=11)
    rep_plain, _ = run_scenario("slo-renegotiation", engine="fast",
                                requests=110_000, seed=11,
                                mid_flight=False)
    assert rep_ev.n_requests >= 100_000
    assert st_ev["session"]["update"] > 10_000
    d_ev = [(t, d.c, d.b) for t, d in rep_ev.decisions]
    d_pl = [(t, d.c, d.b) for t, d in rep_plain.decisions]
    assert len(d_ev) == len(d_pl)
    n_diff = sum(1 for a, b in zip(d_ev, d_pl) if a != b)
    assert n_diff > 0, ("tightening queued budgets must change the "
                        "(c, b) decision stream")


def test_cancel_storm_scenario_end_to_end():
    rep, stats = run_scenario("cancel-storm", engine="fast", duration=80,
                              seed=5)
    assert rep.n_cancelled > 0
    assert stats["session"]["cancel"] == rep.n_cancelled
    rep_plain, _ = run_scenario("cancel-storm", engine="fast",
                                duration=80, seed=5, mid_flight=False)
    assert rep_plain.n_cancelled == 0
    # withdrawn demand must not inflate provisioning: the storm run
    # never allocates more core-seconds than the closed-world replay
    assert rep.core_seconds <= rep_plain.core_seconds + 1e-9
