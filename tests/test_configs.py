"""Config registry and reduced-variant invariants."""
import pytest

from repro.configs import ARCH_IDS, get_config, list_archs
from repro.configs.base import INPUT_SHAPES


def test_all_archs_present():
    assert len(ARCH_IDS) == 10
    for a in ("deepseek-v3-671b", "whisper-large-v3", "qwen2-vl-2b",
              "kimi-k2-1t-a32b", "gemma-2b", "zamba2-2.7b", "smollm-135m",
              "h2o-danube-1.8b", "rwkv6-1.6b", "smollm-360m"):
        assert a in ARCH_IDS


@pytest.mark.parametrize("arch", list_archs())
def test_config_consistency(arch):
    cfg = get_config(arch)
    assert len(cfg.blocks) == cfg.num_layers
    assert cfg.padded_vocab >= cfg.vocab_size
    assert cfg.padded_vocab % 128 == 0
    assert cfg.source, "every config must cite its source"
    if cfg.num_experts:
        assert cfg.num_experts_per_tok <= cfg.num_experts
    if "attn" in cfg.mixer_kinds or "swa" in cfg.mixer_kinds:
        assert cfg.num_heads % cfg.num_kv_heads == 0


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_constraints(arch):
    r = get_config(arch, reduced=True)
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert (r.num_experts or 0) <= 4
    # family preserved
    full = get_config(arch)
    assert r.arch_type == full.arch_type
    assert set(b.split("+")[0] for b in r.blocks) <= \
        set(b.split("+")[0] for b in full.blocks)


def test_assigned_exact_values():
    d = get_config("deepseek-v3-671b")
    assert (d.num_layers, d.d_model, d.num_heads, d.vocab_size,
            d.num_experts, d.num_experts_per_tok, d.moe_d_ff) == \
        (61, 7168, 128, 129280, 256, 8, 2048)
    g = get_config("gemma-2b")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads,
            g.head_dim, g.d_ff, g.vocab_size) == \
        (18, 2048, 8, 1, 256, 16384, 256000)
    z = get_config("zamba2-2.7b")
    assert (z.num_layers, z.d_model, z.ssm_state_dim, z.vocab_size) == \
        (54, 2560, 64, 32000)
    r = get_config("rwkv6-1.6b")
    assert (r.num_layers, r.d_model, r.d_ff, r.vocab_size) == \
        (24, 2048, 7168, 65536)
    w = get_config("whisper-large-v3")
    assert (w.num_layers, w.encoder_layers, w.d_model, w.num_heads,
            w.d_ff, w.vocab_size) == (32, 32, 1280, 20, 5120, 51866)
    k = get_config("kimi-k2-1t-a32b")
    assert (k.num_experts, k.num_kv_heads, k.vocab_size) == (384, 8, 163840)
    q = get_config("qwen2-vl-2b")
    assert (q.num_layers, q.d_model, q.num_heads, q.num_kv_heads,
            q.d_ff, q.vocab_size) == (28, 1536, 12, 2, 8960, 151936)
    h = get_config("h2o-danube-1.8b")
    assert (h.num_layers, h.d_model, h.num_heads, h.num_kv_heads,
            h.d_ff, h.vocab_size, h.window_size) == \
        (24, 2560, 32, 8, 6912, 32000, 4096)
    s1, s2 = get_config("smollm-135m"), get_config("smollm-360m")
    assert (s1.num_layers, s1.d_model, s1.num_heads, s1.num_kv_heads,
            s1.d_ff, s1.vocab_size) == (30, 576, 9, 3, 1536, 49152)
    assert (s2.num_layers, s2.d_model, s2.num_heads, s2.num_kv_heads,
            s2.d_ff, s2.vocab_size) == (32, 960, 15, 5, 2560, 49152)


def test_param_counts_plausible():
    assert 1.1e8 < get_config("smollm-135m").param_count() < 1.9e8
    assert 3.0e8 < get_config("smollm-360m").param_count() < 5.0e8
    assert 5.5e11 < get_config("deepseek-v3-671b").param_count() < 8.0e11
    assert 0.8e12 < get_config("kimi-k2-1t-a32b").param_count() < 1.3e12
    ds = get_config("deepseek-v3-671b")
    assert ds.active_param_count() < 0.1 * ds.param_count()


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["decode_32k"].kind == "decode"
