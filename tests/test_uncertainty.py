"""Distribution-aware admission property suite (ISSUE 7).

Four families:

1. **Quantile conservativeness** — ``quantile(q)`` is the smallest
   supported value whose CDF reaches ``q``, so the mass strictly above
   it can never exceed ``1 - q``; hypothesis sweeps distribution
   parameters and quantiles and checks sampled coverage never exceeds
   the promised tail beyond sampling tolerance.  The same bound holds
   at the engine level: speculative cancel-on-overrun may cut at most
   the promised tail fraction (plus noise) of admitted streams.
2. **Point-mass reduction** — a ``PointMass`` (or ``sigma=0``)
   declaration reduces every uncertainty path to the deterministic
   engines *bit-identically*: same decision stream, same report, both
   token engines (the contract that keeps today's scenarios exact).
3. **Predictor monotonicity** — the coverage-calibrated
   ``LengthPredictor``'s slack factor is monotone non-decreasing in
   its calibration error, and the prior-blended error narrows toward
   zero under sustained correct coverage.
4. **Cancel-on-overrun economics** — overrun cancels free decode
   slots: they never inflate core-seconds versus running the tail to
   completion, and cancelled requests are excluded from every latency
   and violation aggregate (mirroring the PR 5 cancel-storm checks).
"""
import dataclasses
import math

import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.uncertainty import (EmpiricalLengths, LengthDistribution,
                                    LengthPredictor, LognormalLengths,
                                    MixtureLengths, PointMass,
                                    UncertaintyConfig)
from repro.serving.scenarios import (_run_token_scenario, build_scenario,
                                     run_scenario)

C_SET = (1, 2, 4, 8, 16, 24, 32)
B_SET = (1, 2, 4, 8, 16, 32, 64)


# --------------------------------------------------------------------------
# distributions
# --------------------------------------------------------------------------
def test_point_mass_basics():
    d = PointMass(24)
    assert isinstance(d, LengthDistribution)
    assert d.is_point()
    assert d.mean() == 24
    for q in (0.01, 0.5, 0.99):
        assert d.quantile(q) == 24
    assert d.cdf(23) == 0.0 and d.cdf(24) == 1.0
    rng = np.random.default_rng(0)
    assert set(np.asarray(d.sample(rng, 8)).tolist()) == {24}


def test_empirical_quantile_is_order_statistic():
    d = EmpiricalLengths((5, 1, 9, 3, 7))
    assert isinstance(d, LengthDistribution)
    assert not d.is_point()
    # sorted samples (1,3,5,7,9): quantile(q) = ceil(q*5)-th order stat
    assert d.quantile(0.2) == 1
    assert d.quantile(0.5) == 5
    assert d.quantile(0.9) == 9
    assert d.quantile(0.99) == 9
    assert d.mean() == pytest.approx(5.0)


def test_empirical_point_detection():
    assert EmpiricalLengths((4, 4, 4)).is_point()
    assert not EmpiricalLengths((4, 5)).is_point()


def test_lognormal_quantile_inverts_cdf():
    d = LognormalLengths(median=16, sigma=1.4, lo=1, hi=1024)
    assert isinstance(d, LengthDistribution)
    assert not d.is_point()
    for q in (0.1, 0.5, 0.9, 0.99):
        v = d.quantile(q)
        # smallest supported value reaching q: conservativeness depends
        # on exactly this inversion convention
        assert d.cdf(v) >= q
        assert v == 1 or d.cdf(v - 1) < q
    # median lands near the declared median
    assert abs(d.quantile(0.5) - 16) <= 1


def test_lognormal_point_cases():
    assert LognormalLengths(median=16, sigma=0.0).is_point()
    assert LognormalLengths(median=16, sigma=1.0, lo=8, hi=8).is_point()


def test_lognormal_matches_generator():
    """The declared distribution is the generator's: sampled mass per
    decile tracks the analytic CDF."""
    d = LognormalLengths(median=16, sigma=1.4, lo=1, hi=1024)
    rng = np.random.default_rng(3)
    xs = np.asarray(d.sample(rng, 20_000))
    assert xs.min() >= 1 and xs.max() <= 1024
    for q in (0.25, 0.5, 0.75, 0.9):
        v = d.quantile(q)
        frac = float((xs <= v).mean())
        assert abs(frac - d.cdf(v)) < 0.02, (q, v, frac, d.cdf(v))


def test_mixture_cdf_is_weighted_sum():
    a = LognormalLengths(median=16, sigma=0.6, lo=1, hi=128)
    b = LognormalLengths(median=64, sigma=0.9, lo=8, hi=768)
    m = MixtureLengths((a, b), (0.65, 0.35))
    assert isinstance(m, LengthDistribution)
    assert not m.is_point()
    for x in (4, 16, 64, 256):
        assert m.cdf(x) == pytest.approx(0.65 * a.cdf(x) + 0.35 * b.cdf(x))
    assert m.mean() == pytest.approx(0.65 * a.mean() + 0.35 * b.mean())
    for q in (0.1, 0.5, 0.9):
        v = m.quantile(q)
        assert m.cdf(v) >= q
        assert v == 1 or m.cdf(v - 1) < q


def test_mixture_point_detection():
    assert MixtureLengths((PointMass(7), PointMass(7)), (0.5, 0.5)).is_point()
    assert not MixtureLengths((PointMass(7), PointMass(9)),
                              (0.5, 0.5)).is_point()


def test_invalid_quantile_rejected():
    d = LognormalLengths(median=16, sigma=1.0)
    for q in (0.0, 1.0, -0.2, 1.5):
        with pytest.raises(ValueError):
            d.quantile(q)


# --------------------------------------------------------------------------
# 1) quantile conservativeness (hypothesis)
# --------------------------------------------------------------------------
def _coverage_tol(n: int, q: float) -> float:
    return 4.0 * math.sqrt(q * (1.0 - q) / n) + 0.01


@settings(deadline=None, max_examples=40)
@given(median=st.floats(2.0, 80.0), sigma=st.floats(0.05, 2.0),
       q=st.floats(0.05, 0.99), seed=st.integers(0, 2**31 - 1))
def test_lognormal_coverage_never_exceeds_tail(median, sigma, q, seed):
    """P(X > quantile(q)) <= 1 - q, checked on sampled mass."""
    d = LognormalLengths(median=median, sigma=sigma, lo=1, hi=2048)
    rng = np.random.default_rng(seed)
    n = 4000
    xs = np.asarray(d.sample(rng, n))
    over = float((xs > d.quantile(q)).mean())
    assert over <= (1.0 - q) + _coverage_tol(n, q), (over, 1 - q)


@settings(deadline=None, max_examples=40)
@given(seed=st.integers(0, 2**31 - 1), q=st.floats(0.05, 0.99),
       n_samples=st.integers(10, 400))
def test_empirical_coverage_never_exceeds_tail(seed, q, n_samples):
    rng = np.random.default_rng(seed)
    base = rng.integers(1, 500, n_samples)
    d = EmpiricalLengths.from_array(base)
    # exact bound on the defining samples — no sampling noise at all
    over = float((base > d.quantile(q)).mean())
    assert over <= (1.0 - q) + 1e-12, (over, 1 - q)


# --------------------------------------------------------------------------
# 2) point-mass bit-identity on both token engines
# --------------------------------------------------------------------------
def _full_sig(rep):
    return (rep.n_requests, rep.n_violations, rep.n_cancelled,
            rep.core_seconds, rep.tokens_served, rep.ttft_p50, rep.ttft_p99,
            rep.tbt_violation_rate,
            [(t, d.c, d.b, d.n, d.feasible) for t, d in rep.decisions],
            rep.buckets)


@pytest.mark.parametrize("scenario", ["llm-chat", "llm-mixed-len"])
@pytest.mark.parametrize("engine", ["fast", "exact"])
def test_point_mass_reduces_bit_identically(scenario, engine):
    """Declaring a PointMass distribution must reproduce today's
    deterministic run verbatim — decisions, reports, everything."""
    batch, meta = build_scenario(scenario, requests=1200, seed=5)
    kw = dict(policy="sponge", engine=engine, c_set=C_SET, b_set=B_SET,
              c0=16, tick=meta["tick"], horizon=None,
              budget_quantum=0.01, lam_quantum=0.5)
    base, _ = _run_token_scenario(batch, dict(meta), **kw)
    m2 = dict(meta)
    m2["decode_dist"] = PointMass(24)
    b2 = dataclasses.replace(batch, decode_dist=PointMass(24))
    pm, stats = _run_token_scenario(b2, m2, **kw)
    assert stats["uncertainty"]["point"] is True
    assert stats["uncertainty"]["overrun_cancels"] == 0
    assert _full_sig(base) == _full_sig(pm)


def test_sigma_zero_lognormal_is_point_identical():
    batch, meta = build_scenario("llm-chat", requests=800, seed=9)
    kw = dict(policy="sponge", engine="fast", c_set=C_SET, b_set=B_SET,
              c0=16, tick=meta["tick"], horizon=None,
              budget_quantum=0.01, lam_quantum=0.5)
    base, _ = _run_token_scenario(batch, dict(meta), **kw)
    m2 = dict(meta)
    m2["decode_dist"] = LognormalLengths(median=24, sigma=0.0)
    pm, _ = _run_token_scenario(batch, m2, **kw)
    assert _full_sig(base) == _full_sig(pm)


def test_disabled_quantile_is_identical_to_no_dist():
    """admission_quantile=0.0 turns the whole mechanism off even when
    the scenario declares a real distribution."""
    rep0, s0 = run_scenario("llm-heavy-tail", engine="fast",
                            requests=1500, seed=4,
                            admission_quantile=0.0)
    assert "uncertainty" not in s0
    assert rep0.n_cancelled == 0


# --------------------------------------------------------------------------
# 3) predictor calibration -> slack monotonicity
# --------------------------------------------------------------------------
def _predictor_at_overrun_frac(frac: float, tail: float = 0.1,
                               n: int = 256) -> LengthPredictor:
    p = LengthPredictor(window=n)
    n_over = int(round(frac * n))
    for i in range(n):
        actual = 2.0 if i < n_over else 0.0   # predicted = 1.0
        p.observe(1.0, actual, tail=tail)
    return p


def test_slack_monotone_in_calibration_error():
    """More excess overruns ⇒ never less slack (the pinned property)."""
    fracs = [0.0, 0.1, 0.15, 0.3, 0.5, 0.8, 1.0]
    preds = [_predictor_at_overrun_frac(f) for f in fracs]
    errs = [p.calibration_error() for p in preds]
    slacks = [p.slack_factor() for p in preds]
    assert errs == sorted(errs)
    assert slacks == sorted(slacks)
    assert slacks[0] == 1.0                   # perfect coverage: no slack
    assert slacks[-1] > slacks[0]             # gross miscoverage widens


def test_correct_coverage_converges_to_floor():
    """Overrunning exactly as promised is ~zero calibration error
    (exact up to the window's integer-count granularity)."""
    p = _predictor_at_overrun_frac(0.1, tail=0.1)
    assert p.calibration_error() <= 1.0 / p.window + 1e-12
    assert p.slack_factor() == pytest.approx(1.0, abs=0.05)
    p = _predictor_at_overrun_frac(0.25, tail=0.25, n=256)
    assert p.calibration_error() <= 1.0 / p.window + 1e-12


def test_prior_narrows_with_observations():
    p = LengthPredictor(window=100, prior_error=0.05)
    assert p.calibration_error() == pytest.approx(0.05)
    errs = [p.calibration_error()]
    for _ in range(100):
        p.observe(1.0, 0.0, tail=0.1)         # perfectly covered
        errs.append(p.calibration_error())
    assert errs == sorted(errs, reverse=True)  # monotone narrowing
    assert errs[-1] == pytest.approx(0.0)
    assert p.n_observed == 100


def test_overpessimistic_declaration_clips_at_floor():
    """Fewer overruns than promised must not shrink below the quantile."""
    p = _predictor_at_overrun_frac(0.0, tail=0.5)
    assert p.calibration_error() == pytest.approx(0.0)
    assert p.slack_factor() == 1.0


def test_predictor_validation():
    with pytest.raises(ValueError):
        LengthPredictor(window=0)
    with pytest.raises(ValueError):
        LengthPredictor(floor=2.0, cap=1.0)


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**31 - 1))
def test_slack_monotone_under_random_histories(seed):
    """For any observation history, a run with extra overruns stacked on
    top never reports less slack than the original."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 200))
    overruns = rng.uniform(0, 1, n) < rng.uniform(0.05, 0.6)
    a, b = LengthPredictor(window=64), LengthPredictor(window=64)
    for o in overruns:
        a.observe(1.0, 2.0 if o else 0.0, tail=0.1)
        b.observe(1.0, 2.0, tail=0.1)         # b overruns every time
    assert b.calibration_error() >= a.calibration_error() - 1e-12
    assert b.slack_factor() >= a.slack_factor() - 1e-12


# --------------------------------------------------------------------------
# config plumbing
# --------------------------------------------------------------------------
def test_config_validation():
    d = LognormalLengths(median=16, sigma=1.0)
    with pytest.raises(ValueError):
        UncertaintyConfig(dist=d, admission_quantile=1.0)
    with pytest.raises(ValueError):
        UncertaintyConfig(dist=d, overrun_margin=0.5)
    with pytest.raises(ValueError):
        UncertaintyConfig(dist=d, class_quantiles=((0.0, 0.9),))
    with pytest.raises(ValueError):
        UncertaintyConfig(dist=d, class_quantiles=((1.0, 1.5),))


def test_class_quantiles_route_by_slo():
    d = LognormalLengths(median=16, sigma=1.0)
    cfg = UncertaintyConfig(dist=d, admission_quantile=0.9,
                            class_quantiles=((1.0, 0.99), (2.5, 0.8)))
    assert cfg.quantile_for(0.5) == 0.99      # tight class: first bound
    assert cfg.quantile_for(1.0) == 0.99
    assert cfg.quantile_for(2.0) == 0.8
    assert cfg.quantile_for(10.0) == 0.9      # default beyond all bounds
    assert cfg.planned_length(0.5) == d.quantile(0.99)


def test_budget_widens_with_slack():
    d = LognormalLengths(median=16, sigma=1.4, lo=1, hi=1024)
    cfg = UncertaintyConfig(dist=d, admission_quantile=0.9)
    b0 = cfg.budget_tokens(1.0)
    assert b0 >= d.quantile(0.9)
    for _ in range(cfg.predictor.window):      # every stream overruns
        cfg.predictor.observe(1.0, 2.0, tail=0.1)
    assert cfg.budget_tokens(1.0) > b0
    assert cfg.drag_estimate() > d.quantile(0.9)


def test_run_scenario_rejects_quantile_on_non_token():
    with pytest.raises(ValueError):
        run_scenario("steady", engine="fast", requests=200, seed=0,
                     admission_quantile=0.9)


def test_run_scenario_rejects_out_of_range_quantile():
    with pytest.raises(ValueError):
        run_scenario("llm-heavy-tail", engine="fast", requests=200,
                     seed=0, admission_quantile=1.2)


# --------------------------------------------------------------------------
# 4) engine-level conservativeness + cancel-on-overrun economics
# --------------------------------------------------------------------------
@settings(deadline=None, max_examples=5)
@given(seed=st.integers(0, 2**31 - 1))
def test_overrun_cancels_bounded_by_promised_tail(seed):
    """Speculative admission may cancel at most the promised tail mass
    (budgets sit at or above the planned quantile), any workload."""
    rep, stats = run_scenario("llm-heavy-tail", engine="fast",
                              requests=600, seed=seed)
    q = stats["uncertainty"]["quantile"]
    total = rep.n_requests + rep.n_cancelled
    frac = rep.n_cancelled / max(total, 1)
    assert frac <= (1.0 - q) + _coverage_tol(total, q), (frac, 1 - q)


def test_aware_never_more_violations_than_promised():
    rep, stats = run_scenario("llm-heavy-tail", engine="fast",
                              requests=3000, seed=11)
    q = stats["uncertainty"]["quantile"]
    assert rep.violation_rate <= (1.0 - q) + _coverage_tol(
        max(rep.n_requests, 1), q)


@pytest.mark.parametrize("engine", ["fast", "exact"])
def test_overrun_cancels_free_slots_not_inflate_cost(engine):
    """The satellite regression: cancelling the tail must not cost more
    core-seconds than running it to completion, cancels must be real,
    and cancelled requests must stay out of the latency aggregates."""
    common = dict(engine=engine, requests=2500, seed=13)
    spec, s_on = run_scenario("llm-heavy-tail", **common)
    nospec, s_off = run_scenario("llm-heavy-tail", speculative=False,
                                 **common)
    assert spec.n_cancelled > 0
    assert s_on["uncertainty"]["overrun_cancels"] == spec.n_cancelled
    assert nospec.n_cancelled == 0
    assert s_off["uncertainty"]["overrun_cancels"] == 0
    # same workload: every request is either served or cancelled
    assert spec.n_requests + spec.n_cancelled == nospec.n_requests
    # freeing the tail's slots can only cheapen the run
    assert spec.core_seconds <= nospec.core_seconds + 1e-9
    # cancelled requests never enter latency/violation aggregates: the
    # served population is smaller yet every percentile stays finite
    assert np.isfinite(spec.ttft_p99) and np.isfinite(spec.p99)
    assert spec.n_violations <= spec.n_requests


def test_exact_engine_overrun_cancels_route_through_monitor():
    """Exact-engine overruns go through Monitor.observe_cancel: the λ
    window retracts and the request is reported cancelled, mirroring
    the PR 5 cancel machinery."""
    rep, stats = run_scenario("llm-heavy-tail", engine="exact",
                              requests=1200, seed=3)
    assert rep.n_cancelled > 0
    assert rep.n_cancelled == stats["uncertainty"]["overrun_cancels"]
    assert rep.n_requests + rep.n_cancelled >= 1000


def test_retrieve_then_generate_runs_with_class_quantiles():
    """The RAG scenario carries per-class quantiles end to end."""
    rep, stats = run_scenario("retrieve-then-generate", engine="fast",
                              requests=2000, seed=8)
    unc = stats["uncertainty"]
    assert unc["speculative"] is True
    assert rep.n_cancelled > 0
    assert rep.n_requests > 0
    assert np.isfinite(rep.ttft_p99)


def test_calibration_feedback_reaches_solver():
    """The shared config closes the loop: after a run the predictor has
    observed streams and its slack is a finite factor >= 1."""
    _rep, stats = run_scenario("llm-heavy-tail", engine="fast",
                               requests=2000, seed=21)
    unc = stats["uncertainty"]
    assert unc["n_observed"] > 0
    assert 1.0 <= unc["slack_factor"] <= 3.0
    assert unc["calibration_error"] >= 0.0
