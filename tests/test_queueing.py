"""EDF queue + dynamic batcher property tests."""
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.queueing import DynamicBatcher, EDFQueue
from repro.core.slo import Request


reqs = st.lists(
    st.tuples(st.floats(0, 100), st.floats(0.0, 0.8), st.floats(0.1, 2.0)),
    min_size=0, max_size=50)


@given(reqs)
@settings(deadline=None)
def test_edf_order(entries):
    q = EDFQueue()
    for arr, cl, slo in entries:
        q.push(Request.make(arrival=arr, comm_latency=cl, slo=slo))
    deadlines = [q.pop().deadline for _ in range(len(q))]
    assert deadlines == sorted(deadlines)


@given(reqs, st.integers(1, 8))
@settings(deadline=None)
def test_pop_batch_respects_edf_and_size(entries, b):
    q = EDFQueue()
    rs = [Request.make(arrival=a, comm_latency=c, slo=s)
          for a, c, s in entries]
    q.extend(rs)
    batcher = DynamicBatcher(q, b)
    seen = []
    while batcher.has_work():
        batch = batcher.next_batch()
        assert 1 <= len(batch) <= b
        seen.extend(r.deadline for r in batch)
    assert seen == sorted(seen)
    assert len(seen) == len(rs)


@given(reqs, st.floats(0, 120))
@settings(deadline=None)
def test_drop_expired(entries, now):
    q = EDFQueue()
    for a, c, s in entries:
        q.push(Request.make(arrival=a, comm_latency=c, slo=s))
    n0 = len(q)
    dropped = q.drop_expired(now)
    assert len(q) + len(dropped) == n0
    for r in dropped:
        assert r.deadline < now
    for _ in range(len(q)):
        assert q.pop().deadline >= now


def test_snapshot_remaining_sorted():
    q = EDFQueue()
    for a in (5.0, 1.0, 3.0):
        q.push(Request.make(arrival=a, comm_latency=0.1, slo=1.0))
    snap = q.snapshot_remaining(now=0.5)
    assert snap == sorted(snap)
    assert len(snap) == 3
