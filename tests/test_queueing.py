"""EDF queue + dynamic batcher property tests, plus the mid-flight
renegotiation edge cases (ISSUE 5) across all three queue substrates."""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.queueing import (DynamicBatcher, EDFQueue, FastEDFQueue,
                                 TokenFastEDFQueue)
from repro.core.slo import Request


reqs = st.lists(
    st.tuples(st.floats(0, 100), st.floats(0.0, 0.8), st.floats(0.1, 2.0)),
    min_size=0, max_size=50)


@given(reqs)
@settings(deadline=None)
def test_edf_order(entries):
    q = EDFQueue()
    for arr, cl, slo in entries:
        q.push(Request.make(arrival=arr, comm_latency=cl, slo=slo))
    deadlines = [q.pop().deadline for _ in range(len(q))]
    assert deadlines == sorted(deadlines)


@given(reqs, st.integers(1, 8))
@settings(deadline=None)
def test_pop_batch_respects_edf_and_size(entries, b):
    q = EDFQueue()
    rs = [Request.make(arrival=a, comm_latency=c, slo=s)
          for a, c, s in entries]
    q.extend(rs)
    batcher = DynamicBatcher(q, b)
    seen = []
    while batcher.has_work():
        batch = batcher.next_batch()
        assert 1 <= len(batch) <= b
        seen.extend(r.deadline for r in batch)
    assert seen == sorted(seen)
    assert len(seen) == len(rs)


@given(reqs, st.floats(0, 120))
@settings(deadline=None)
def test_drop_expired(entries, now):
    q = EDFQueue()
    for a, c, s in entries:
        q.push(Request.make(arrival=a, comm_latency=c, slo=s))
    n0 = len(q)
    dropped = q.drop_expired(now)
    assert len(q) + len(dropped) == n0
    for r in dropped:
        assert r.deadline < now
    for _ in range(len(q)):
        assert q.pop().deadline >= now


def test_snapshot_remaining_sorted():
    q = EDFQueue()
    for a in (5.0, 1.0, 3.0):
        q.push(Request.make(arrival=a, comm_latency=0.1, slo=1.0))
    snap = q.snapshot_remaining(now=0.5)
    assert snap == sorted(snap)
    assert len(snap) == 3


# --------------------------------------------------------------------------
# mid-flight renegotiation edge cases (ISSUE 5), all three substrates.
# Each substrate is driven through a tiny adapter so every edge case runs
# verbatim against the object heap, the index heap and the token heap.
# --------------------------------------------------------------------------
class _ObjQ:
    """EDFQueue adapter: keys are request ids."""

    def __init__(self):
        self.q = EDFQueue()
        self._reqs = {}

    def push(self, key, deadline):
        r = Request(deadline=deadline, arrival=0.0)
        self._reqs[key] = r
        self.q.push(r)

    def key_of(self, req):
        return next(k for k, r in self._reqs.items() if r is req)

    def update(self, key, dl):
        return self.q.update_deadline(self._reqs[key].id, dl)

    def cancel(self, key):
        return self.q.cancel(self._reqs[key].id) is not None

    def pop_batch(self, b):
        return [self.key_of(r) for r in self.q.pop_batch(b)]

    def __len__(self):
        return len(self.q)

    def head_deadline(self):
        return self.q.peek().deadline

    def remaining(self, now):
        return self.q.remaining_array(now)


class _IdxQ:
    """FastEDFQueue adapter: keys are the indices themselves."""

    make = FastEDFQueue

    def __init__(self):
        self.q = self.make()

    def push(self, key, deadline):
        self.q.push(deadline, key)

    def update(self, key, dl):
        return self.q.update_deadline(key, dl)

    def cancel(self, key):
        return self.q.cancel(key)

    def pop_batch(self, b):
        return self.q.pop_batch(b)

    def __len__(self):
        return len(self.q)

    def head_deadline(self):
        return self.q.peek_deadline()

    def remaining(self, now):
        return self.q.remaining_array(now)


class _TokQ(_IdxQ):
    make = TokenFastEDFQueue

    def __init__(self):
        super().__init__()
        self.q.bind(np.arange(1, 64, dtype=np.int64),
                    np.full(63, 0.1))


SUBSTRATES = [_ObjQ, _IdxQ, _TokQ]


@pytest.fixture(params=SUBSTRATES, ids=["object", "index", "token"])
def q(request):
    return request.param()


def _fill(q, deadlines):
    for k, dl in enumerate(deadlines):
        q.push(k, float(dl))


def test_update_reorders_head_vs_tail(q):
    _fill(q, [2.0, 4.0, 6.0, 8.0])
    assert q.head_deadline() == 2.0
    assert q.update(3, 1.0)            # tail becomes the head
    assert q.head_deadline() == 1.0
    assert q.update(0, 9.0)            # old head sinks to the back
    assert q.pop_batch(10) == [3, 1, 2, 0]
    assert len(q) == 0


def test_update_to_past_deadline_front_runs(q):
    """A budget tightened below `now` is overdue, not lost: EDF must
    front-run it on the next dispatch."""
    _fill(q, [5.0, 7.0])
    assert q.update(1, -1.0)
    rem = q.remaining(now=0.0)
    assert rem[0] == -1.0 and len(rem) == 2
    assert q.pop_batch(1) == [1]


def test_cancel_then_dispatch_race(q):
    """A cancel racing the dispatcher: the popped batch must skip the
    cancelled entry and take the next live one instead."""
    _fill(q, [1.0, 2.0, 3.0])
    assert q.cancel(0)                 # cancel the head just before pop
    assert q.pop_batch(2) == [1, 2]
    assert len(q) == 0


def test_double_cancel_and_cancel_after_dispatch(q):
    _fill(q, [1.0, 2.0])
    assert q.cancel(1)
    assert not q.cancel(1)             # double-cancel is a no-op
    assert q.pop_batch(1) == [0]
    assert not q.cancel(0)             # already dispatched
    assert not q.update(0, 5.0)        # ...and not renegotiable either


def test_update_after_cancel_refused(q):
    _fill(q, [1.0])
    assert q.cancel(0)
    assert not q.update(0, 0.5)
    assert len(q) == 0 and q.pop_batch(4) == []


def test_update_noop_same_deadline_keeps_single_entry(q):
    _fill(q, [3.0, 4.0])
    assert q.update(0, 3.0)            # no-op re-key
    assert q.pop_batch(10) == [0, 1]   # no duplicate surfaces


def test_snapshots_see_only_live_entries(q):
    _fill(q, [2.0, 3.0, 4.0, 5.0])
    q.cancel(1)
    q.update(2, 1.0)
    rem = q.remaining(now=0.0)
    assert list(rem) == [1.0, 2.0, 5.0]
    assert len(q) == 3


def test_update_churn_preserves_edf_order(q):
    """Repeated re-keying of the same entries (fade, recovery, fade)
    leaves exactly one live entry per key and a clean EDF order."""
    _fill(q, [5.0, 6.0, 7.0])
    for dl in (2.0, 9.0, 4.0):
        assert q.update(1, dl)
    assert q.pop_batch(10) == [1, 0, 2]
    assert len(q) == 0


def test_token_snapshot_after_renegotiation():
    tq = _TokQ()
    _fill(tq, [4.0, 2.0, 6.0])
    tq.update(2, 1.0)
    tq.cancel(0)
    rem, toks, tbt = tq.q.token_snapshot(now=0.0)
    # EDF order: idx 2 (dl 1.0) then idx 1 (dl 2.0); prompt column is
    # arange(1, ...) so tokens align as idx+1
    assert list(rem) == [1.0, 2.0]
    assert list(toks) == [3.0, 2.0]
    assert tbt == pytest.approx(0.1)


def test_object_queue_drop_expired_with_stale_entries():
    oq = _ObjQ()
    _fill(oq, [1.0, 5.0, 9.0])
    oq.update(1, 0.5)                  # stale tuple for dl=5.0 remains
    dropped = oq.q.drop_expired(now=2.0)
    assert sorted(r.deadline for r in dropped) == [0.5, 1.0]
    assert len(oq) == 1 and oq.head_deadline() == 9.0


# --------------------------------------------------------------------------
# bulk push_many / pop_ready (ISSUE 8 satellite): the vectorpath's batch
# ingestion and windowed dispatch primitives must be order-identical to
# sequential push / pop_batch calls, across every internal path (sorted-
# block adoption, extend+heapify, per-item sift) and against interleaved
# re-keys and cancels.
# --------------------------------------------------------------------------
bulk_dls = st.lists(st.floats(0.0, 100.0), min_size=0, max_size=60)


def _drain(q):
    out = []
    while len(q):
        out.extend(q.pop_batch(1))
    return out


@given(bulk_dls, st.integers(1, 5))
@settings(deadline=None)
def test_push_many_order_identical_to_sequential(dls, n_chunks):
    """Chunked push_many (hitting the sorted-block, heapify and sift
    paths depending on chunk shape) pops in exactly the sequential
    push order."""
    seq, bulk = FastEDFQueue(), FastEDFQueue()
    for i, dl in enumerate(dls):
        seq.push(dl, i)
    idxs = np.arange(len(dls), dtype=np.int64)
    arr = np.asarray(dls, np.float64)
    for part_d, part_i in zip(np.array_split(arr, n_chunks),
                              np.array_split(idxs, n_chunks)):
        bulk.push_many(part_d, part_i)
    assert len(bulk) == len(seq)
    assert _drain(bulk) == _drain(seq)


def test_push_many_sorted_block_fast_path():
    """An already-sorted block into an empty queue IS the heap."""
    q = FastEDFQueue()
    q.push_many([1.0, 2.0, 3.0, 3.0], [0, 1, 2, 3])
    assert q.peek_deadline() == 1.0
    assert _drain(q) == [0, 1, 2, 3]


@given(bulk_dls, st.integers(1, 8), st.floats(0.0, 120.0))
@settings(deadline=None)
def test_pop_ready_matches_model(dls, b, before):
    """pop_ready(b, before) = the ≤b earliest (deadline, idx) pairs
    with deadline strictly below the bound, removed from the queue."""
    q = FastEDFQueue()
    q.push_many(np.asarray(dls, np.float64),
                np.arange(len(dls), dtype=np.int64))
    model = sorted((dl, i) for i, dl in enumerate(dls))
    want = [i for dl, i in model if dl < before][:b]
    got = q.pop_ready(b, before=before)
    assert got == want
    assert len(q) == len(dls) - len(want)
    assert _drain(q) == [i for dl, i in model if (dl, i) not in
                         {(dls[j], j) for j in want}]


def test_pop_ready_exclusive_bound_and_empty():
    q = FastEDFQueue()
    assert q.pop_ready(4) == []
    q.push_many([2.0, 1.0, 3.0], [0, 1, 2])
    assert q.pop_ready(5, before=1.0) == []      # strict: dl < before
    assert q.pop_ready(5, before=2.0) == [1]
    assert q.pop_ready(5) == [0, 2]              # before=inf == pop_batch


def test_bulk_ops_with_renegotiation_and_cancels():
    """Stale tuples from update_deadline/cancel between bulk calls are
    discarded, never served; re-keyed entries pop at their new rank."""
    q = FastEDFQueue()
    q.push_many([5.0, 6.0, 7.0, 8.0], [0, 1, 2, 3])
    assert q.update_deadline(3, 1.0)             # tighten: jumps the line
    assert q.cancel(1)
    q.push_many([6.5, 0.5], [4, 5])              # second block, non-empty heap
    assert q.pop_ready(2, before=5.0) == [5, 3]
    assert q.update_deadline(0, 9.0)             # relax behind idx 2
    assert _drain(q) == [4, 2, 0]
    assert q.pop_ready(3) == []
