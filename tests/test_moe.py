"""MoE dispatch-path equivalence tests (single-device + subprocess SPMD)."""
import json
import os
import subprocess
import sys

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import (capacity_for, init_moe, moe_fwd, route_topk)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tiny_cfg(**kw):
    cfg = get_config("kimi-k2-1t-a32b", reduced=True)
    return dataclasses.replace(cfg, num_experts=8, num_experts_per_tok=2,
                               d_model=64, moe_d_ff=32,
                               moe_capacity_factor=8.0, **kw)


def test_capacity_floor_and_cap():
    assert capacity_for(8, 8, 256, 1.25) >= 8       # decode: zero-drop floor
    assert capacity_for(1, 2, 4, 1.25) <= 2          # never exceeds t*k
    c = capacity_for(65536, 8, 256, 1.25)
    assert c >= 65536 * 8 * 1.25 / 256
    assert c % 4 == 0


def test_route_topk_softmax_vs_sigmoid():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((16, 8)),
                         jnp.float32)
    bias = jnp.zeros((8,))
    for kind in ("softmax", "sigmoid"):
        w, ids, probs = route_topk(logits, bias, 2, kind)
        assert w.shape == (16, 2) and ids.shape == (16, 2)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
        assert int(ids.max()) < 8


def test_sigmoid_bias_changes_selection_not_weights():
    """DeepSeek-V3 aux-free balancing: the bias shifts WHICH experts are
    picked but the combine weights come from unbiased scores."""
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    b0 = jnp.zeros((8,))
    b1 = b0.at[3].set(10.0)  # strongly favor expert 3
    _, ids0, _ = route_topk(logits, b0, 2, "sigmoid")
    w1, ids1, _ = route_topk(logits, b1, 2, "sigmoid")
    assert (ids1 == 3).any(axis=1).all(), "bias must pull expert 3 in"
    # weights still normalized from sigmoid scores
    np.testing.assert_allclose(np.asarray(w1.sum(-1)), 1.0, atol=1e-5)


def test_moe_fwd_no_drop_equals_dense_sum():
    """With no-drop capacity, the MoE output equals the explicit per-token
    weighted sum of expert FFNs."""
    cfg = _tiny_cfg()
    params = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 6, 64)) * 0.5
    y, aux = jax.jit(lambda p, x: moe_fwd(p, x, cfg))(params, x)

    xt = x.reshape(-1, 64)
    logits = xt @ params["router"]
    w, ids, _ = route_topk(logits, params["router_bias"],
                           cfg.num_experts_per_tok, cfg.moe_router_kind)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = xt @ params["wg"][e]
        u = xt @ params["wu"][e]
        fe = (jax.nn.silu(h) * u) @ params["wd"][e]
        we = jnp.where(ids == e, w, 0.0).sum(-1)
        ref = ref + fe * we[:, None]
    from repro.models.mlp import mlp_fwd
    if "shared" in params:
        ref = ref + mlp_fwd(params["shared"], xt, "swiglu")
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 64)),
                               np.asarray(ref), atol=1e-4, rtol=1e-4)


PARTIAL_EP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models.moe import init_moe, moe_fwd, moe_fwd_ep

cfg = get_config("kimi-k2-1t-a32b", reduced=True)
cfg = dataclasses.replace(cfg, num_experts=8, num_experts_per_tok=2,
                          d_model=64, moe_d_ff=32, moe_capacity_factor=8.0)
params = init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (8, 4, 64)) * 0.5
y_ref, _ = jax.jit(lambda p, x: moe_fwd(p, x, cfg))(params, x)
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg2 = dataclasses.replace(cfg, moe_partial_ep=True)
with mesh:
    y_ep, _ = jax.jit(lambda p, x: moe_fwd_ep(
        p, x, cfg2, mesh, ("data",), "model"))(params, x)
    y_g, _ = jax.jit(lambda p, x: moe_fwd_ep(
        p, x, cfg, mesh, ("data",), "model"))(params, x)
print(json.dumps({"partial": float(jnp.abs(y_ep - y_ref).max()),
                  "gather": float(jnp.abs(y_g - y_ref).max())}))
"""


@pytest.mark.slow
def test_expert_parallel_paths_match_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", PARTIAL_EP_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["partial"] < 1e-4, r
    assert r["gather"] < 1e-4, r
