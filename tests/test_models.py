"""Per-architecture smoke + prefill/decode equivalence tests.

Every assigned arch instantiates its REDUCED variant (<=2 layers,
d_model<=512, <=4 experts), runs one forward and one train step on CPU, and
asserts output shapes + no NaNs.  The equivalence test checks that
prefill + single-token decode reproduce the full-forward logits — the
strongest correctness property the serving path has.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.data import make_batch
from repro.models import build_model
from repro.train.loop import init_state, make_train_step
from repro.train.optimizer import OptConfig

B, S = 2, 12


def mk_batch(cfg, rng_seed=1, with_labels=False):
    rng = jax.random.key(rng_seed)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = batch["tokens"]
    if cfg.num_patch_tokens:
        p = cfg.num_patch_tokens
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            rng, (B, p, cfg.d_model), jnp.float32)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S + p, dtype=jnp.int32), (3, B, S + p))
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, reduced=True)
            m = build_model(cfg)
            params = m.init(jax.random.key(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", list_archs())
def test_forward_smoke(arch, built):
    cfg, m, params = built(arch)
    batch = mk_batch(cfg)
    logits, aux = jax.jit(m.forward)(params, batch)
    s_total = S + (cfg.num_patch_tokens or 0)
    assert logits.shape == (B, s_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch, built):
    cfg, m, _ = built(arch)
    oc = OptConfig(lr=1e-3)
    state = init_state(m, jax.random.key(0), oc).as_dict()
    batch = make_batch(cfg, B, S + (cfg.num_patch_tokens or 0), 0)
    step = jax.jit(make_train_step(m, oc))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    l0 = jax.tree.leaves(state["params"])[0]
    assert not bool(jnp.isnan(l0).any())


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch, built):
    cfg, m, params = built(arch)
    batch = mk_batch(cfg)
    p = cfg.num_patch_tokens or 0
    logits_full, _ = jax.jit(m.forward)(params, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    if cfg.rope_kind == "mrope":
        pre["mrope_positions"] = batch["mrope_positions"][:, :, :p + S - 1]
    cl = p + S + 4
    last_logits, cache = jax.jit(
        lambda pp, bb: m.prefill(pp, bb, cache_len=cl))(params, pre)
    np.testing.assert_allclose(np.asarray(last_logits, np.float32),
                               np.asarray(logits_full[:, -2], np.float32),
                               atol=2e-2, rtol=2e-2)

    tok = batch["tokens"][:, S - 1:S]
    if cfg.rope_kind == "mrope":
        mp = batch["mrope_positions"][:, :, -1:]
        dec, _ = jax.jit(lambda pp, cc, tt, mm: m.decode_step(
            pp, cc, tt, mm))(params, cache, tok, mp)
    else:
        dec, _ = jax.jit(lambda pp, cc, tt: m.decode_step(
            pp, cc, tt))(params, cache, tok)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["zamba2-2.7b", "rwkv6-1.6b",
                                  "h2o-danube-1.8b"])
def test_multi_step_decode(arch, built):
    """Sub-quadratic archs: 4 consecutive decode steps match the forward."""
    cfg, m, params = built(arch)
    batch = mk_batch(cfg)
    logits_full, _ = jax.jit(m.forward)(params, batch)
    k = 4
    pre = {"tokens": batch["tokens"][:, :S - k]}
    _, cache = jax.jit(lambda pp, bb: m.prefill(
        pp, bb, cache_len=S + 4))(params, pre)
    dec_fn = jax.jit(lambda pp, cc, tt: m.decode_step(pp, cc, tt))
    for i in range(k):
        tok = batch["tokens"][:, S - k + i:S - k + i + 1]
        logits, cache = dec_fn(params, cache, tok)
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(logits_full[:, S - k + i], np.float32),
            atol=3e-2, rtol=3e-2)


def test_swa_ring_buffer_long_decode(built):
    """Decode beyond the SWA window exercises the ring buffer."""
    cfg, m, params = built("h2o-danube-1.8b")
    w = cfg.window_size
    assert w == 16  # reduced
    s_long = w + 8
    toks = jax.random.randint(jax.random.key(3), (B, s_long), 0,
                              cfg.vocab_size)
    logits_full, _ = jax.jit(m.forward)(params, {"tokens": toks})
    pre = {"tokens": toks[:, :s_long - 1]}
    _, cache = jax.jit(lambda pp, bb: m.prefill(
        pp, bb, cache_len=s_long + 2))(params, pre)
    dec, _ = jax.jit(lambda pp, cc, tt: m.decode_step(pp, cc, tt))(
        params, cache, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(logits_full[:, -1], np.float32),
                               atol=3e-2, rtol=3e-2)


def test_mtp_loss_present():
    cfg = get_config("deepseek-v3-671b", reduced=True)
    assert cfg.mtp_depth == 1
    m = build_model(cfg)
    oc = OptConfig()
    state = init_state(m, jax.random.key(0), oc).as_dict()
    batch = make_batch(cfg, B, S, 0)
    _, metrics = jax.jit(make_train_step(m, oc))(state, batch)
    assert "mtp_ce" in metrics and np.isfinite(float(metrics["mtp_ce"]))
