"""spongelint framework tests: every rule catches its seeded fixture
violation, suppressions work, and the real tree is clean.

The fixtures live in ``tests/fixtures/spongelint`` (not collected as
tests; excluded from ruff).  The final tests are the PR's acceptance
criteria: ``src/`` lints clean, and mutating the annotated inlined
``_Slot.account`` block inside ``vectorpath`` makes the lint fail.
"""
from pathlib import Path

from tools.spongelint import REPO, RULES, lint_file, lint_paths
from tools.spongelint.__main__ import main
from tools.spongelint.astnorm import alpha_equal, fingerprint
from tools.spongelint.resolve import TargetResolver

FIX = Path(__file__).resolve().parent / "fixtures" / "spongelint"


def lint_fixture(name, select=None):
    return lint_file(FIX / name, TargetResolver([FIX]), select=select)


# -- registry ---------------------------------------------------------------
def test_rule_registry():
    assert set(RULES) == {"inline-drift", "determinism", "scan-purity",
                          "deprecation-hygiene"}
    for r in RULES.values():
        assert r.summary


# -- inline-drift -----------------------------------------------------------
def test_faithful_inline_is_clean():
    assert lint_fixture("good_inline.py") == []


def test_drifted_inline_is_caught():
    findings = lint_fixture("drifted_inline.py")
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "inline-drift"
    assert "drifted" in f.message
    assert "fixpkg.canonical.window_rate" in f.message


def test_alpha_equivalence_is_consistent_renaming():
    import ast
    canon = ast.parse("def f(a, b):\n    return a + b").body[0]
    ok = ast.parse("return x + y", mode="exec").body
    bad = ast.parse("return x + x", mode="exec").body
    assert alpha_equal(ok, canon)
    assert not alpha_equal(bad, canon)


def test_pin_matches_and_breaks(tmp_path):
    (tmp_path / "canon.py").write_text(
        "def rate(n, s):\n    if n == 0:\n        return 0.0\n"
        "    return n / s\n")
    resolver = TargetResolver([tmp_path])
    _, func = resolver.resolve("canon.rate")
    pin = fingerprint(func)

    good = tmp_path / "user_good.py"
    good.write_text(
        f"# spongelint: inline-of canon.rate pin={pin}\n"
        "def mine(k, t):\n    return 0.0 if k == 0 else k / t\n")
    assert lint_file(good, resolver) == []

    stale = tmp_path / "user_stale.py"
    stale.write_text(
        "# spongelint: inline-of canon.rate pin=000000000000\n"
        "def mine(k, t):\n    return 0.0 if k == 0 else k / t\n")
    findings = lint_file(stale, resolver)
    assert len(findings) == 1
    assert findings[0].rule == "inline-drift"
    assert "re-stamp" in findings[0].message


def test_pin_survives_rename_and_docstring_edit(tmp_path):
    v1 = "def rate(n, s):\n    '''doc one'''\n    return n / s\n"
    v2 = "def rate(count, span):\n    '''doc two'''\n    return count / span\n"
    v3 = "def rate(n, s):\n    s = s + 1\n    return n / s\n"
    pins = []
    for src in (v1, v2, v3):
        (tmp_path / "canon.py").write_text(src)
        _, func = TargetResolver([tmp_path]).resolve("canon.rate")
        pins.append(fingerprint(func))
    assert pins[0] == pins[1]          # alpha-rename + docstring: stable
    assert pins[0] != pins[2]          # statement-level change: breaks


def test_unresolvable_target_is_reported(tmp_path):
    bad = tmp_path / "user.py"
    bad.write_text("# spongelint: inline-of no.such.module.fn\nX = 1\n")
    findings = lint_file(bad, TargetResolver([tmp_path]))
    assert len(findings) == 1
    assert "cannot resolve" in findings[0].message


# -- determinism ------------------------------------------------------------
def test_determinism_catches_each_seeded_violation():
    findings = lint_fixture("serving/bad_time.py")
    assert all(f.rule == "determinism" for f in findings)
    msgs = "\n".join(f.message for f in findings)
    assert "time.time" in msgs
    assert "random.random" in msgs
    assert "numpy.random.rand" in msgs
    assert "without a seed" in msgs
    assert "iteration over a set" in msgs
    assert "comprehension over a set" in msgs
    assert len(findings) == 6


def test_determinism_allows_telemetry_clock_and_seeded_rng():
    assert lint_fixture("serving/good_time.py") == []


def test_determinism_scoped_to_hot_paths(tmp_path):
    # same violations outside a serving/ or core/ path: out of scope
    (tmp_path / "elsewhere.py").write_text(
        "import time\n\ndef f():\n    return time.time()\n")
    assert lint_file(tmp_path / "elsewhere.py",
                     TargetResolver([tmp_path])) == []


def test_suppression_silences_with_reason():
    assert lint_fixture("serving/suppressed.py") == []


def test_unknown_suppression_and_directive_are_findings():
    findings = lint_fixture("bad_directive.py")
    assert len(findings) == 2
    assert all(f.rule == "bad-directive" for f in findings)


# -- scan-purity ------------------------------------------------------------
def test_scan_purity_catches_impure_step():
    findings = lint_fixture("impure_scan.py")
    assert all(f.rule == "scan-purity" for f in findings)
    msgs = "\n".join(f.message for f in findings)
    assert ".append" in msgs and "print" in msgs
    assert len(findings) == 2


def test_scan_purity_accepts_pure_step(tmp_path):
    (tmp_path / "pure.py").write_text(
        "from jax import lax\n\n"
        "def step(carry, x):\n    return carry + x, carry\n\n"
        "def run(xs):\n    return lax.scan(step, 0.0, xs)\n")
    assert lint_file(tmp_path / "pure.py", TargetResolver([tmp_path])) == []


# -- deprecation-hygiene ----------------------------------------------------
def test_deprecation_catches_all_three_shims():
    findings = lint_fixture("deprecated_import.py")
    assert all(f.rule == "deprecation-hygiene" for f in findings)
    msgs = "\n".join(f.message for f in findings)
    assert "repro.serving.simulator" in msgs
    assert "repro.serving.engine" in msgs
    assert "repro.core.multidim" in msgs
    assert len(findings) == 3


def test_deprecation_exempts_test_files(tmp_path):
    src = (FIX / "deprecated_import.py").read_text()
    (tmp_path / "test_shims.py").write_text(src)
    assert lint_file(tmp_path / "test_shims.py",
                     TargetResolver([tmp_path])) == []


# -- acceptance: the real tree ----------------------------------------------
def test_src_tree_is_clean():
    assert lint_paths([REPO / "src"]) == []


def test_tools_and_benchmarks_are_clean():
    assert lint_paths([REPO / "tools", REPO / "benchmarks"]) == []


def test_mutating_annotated_inline_fails(tmp_path):
    """Reordering the two statements of vectorpath's inlined
    ``_Slot.account`` block must break the lint (acceptance criterion)."""
    vp = (REPO / "src" / "repro" / "serving" / "vectorpath.py").read_text()
    marker = "# spongelint: inline-of repro.serving.fastpath._Slot.account"
    lines = vp.splitlines(keepends=True)
    idx = next(i for i, ln in enumerate(lines) if marker in ln)
    a, b = lines[idx + 1], lines[idx + 2]
    assert "core_seconds" in a and "_last_t" in b
    lines[idx + 1], lines[idx + 2] = b, a
    mutated = tmp_path / "vectorpath_mutated.py"
    mutated.write_text("".join(lines))
    findings = lint_file(mutated, TargetResolver([REPO / "src", REPO]),
                         select=["inline-drift"])
    assert any(f.rule == "inline-drift" and "drifted" in f.message
               for f in findings)


# -- CLI --------------------------------------------------------------------
def test_cli_exit_codes(capsys):
    assert main(["--list-rules"]) == 0
    assert main([str(FIX / "good_inline.py"), "--root", str(FIX)]) == 0
    assert main([str(FIX / "drifted_inline.py"), "--root", str(FIX)]) == 1
    out = capsys.readouterr()
    assert "inline-drift" in out.out


def test_cli_print_pin(capsys):
    assert main(["--print-pin", "fixpkg.canonical.window_rate",
                 "--root", str(FIX)]) == 0
    pin = capsys.readouterr().out.strip()
    _, func = TargetResolver([FIX]).resolve("fixpkg.canonical.window_rate")
    assert pin == fingerprint(func)
    assert main(["--print-pin", "no.such.thing", "--root", str(FIX)]) == 2
