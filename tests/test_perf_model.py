"""Performance-model (Eq. 2) fitting tests, incl. robustness (Fig. 3)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.perf_model import (PerfModel, TABLE1_SAMPLES, fit_table1,
                                   yolov5s_like)


def test_table1_fit_quality():
    pm = fit_table1()
    assert pm.r2 > 0.9
    # reproduces the paper's measured points within ~20%
    for b, c, l in TABLE1_SAMPLES:
        assert abs(pm.latency(b, c) - l) / l < 0.35


def test_latency_monotonicity():
    pm = yolov5s_like()
    bs = np.arange(1, 17)
    for c in (1, 2, 4, 8, 16):
        l = pm.latency(bs, c)
        assert np.all(np.diff(l) > 0), "latency increases with batch"
    for b in (1, 4, 16):
        l = pm.latency(b, np.arange(1, 17))
        assert np.all(np.diff(l) < 0), "latency decreases with cores"


def test_amdahl_floor():
    pm = yolov5s_like()
    # as c -> inf, latency approaches delta*b + eta (the serial fraction)
    assert pm.latency(4, 1e9) == pytest.approx(pm.delta * 4 + pm.eta,
                                               rel=1e-6)


@given(st.floats(0.01, 0.5), st.floats(0.001, 0.1), st.floats(0.0005, 0.05),
       st.floats(0.001, 0.05))
@settings(max_examples=50, deadline=None)
def test_fit_recovers_ground_truth(gamma, eps, delta, eta):
    truth = PerfModel(gamma=gamma, eps=eps, delta=delta, eta=eta)
    samples = truth.sample_profile(range(1, 17), (1, 2, 4, 8, 16),
                                   noise=0.0)
    fit = PerfModel.fit(samples, robust=False)
    bs, cs = np.meshgrid(np.arange(1, 17), np.arange(1, 17))
    np.testing.assert_allclose(fit.latency(bs, cs), truth.latency(bs, cs),
                               rtol=1e-4, atol=1e-7)


def test_ransac_rejects_outliers():
    truth = yolov5s_like()
    dirty = truth.sample_profile(range(1, 17), (1, 2, 4, 8, 16),
                                 noise=0.01, outlier_frac=0.15, seed=3)
    robust = PerfModel.fit(dirty, robust=True, seed=1)
    naive = PerfModel.fit(dirty, robust=False)
    bs, cs = np.meshgrid(np.arange(1, 17), np.arange(1, 17))
    err_r = np.abs(robust.latency(bs, cs) - truth.latency(bs, cs)).mean()
    err_n = np.abs(naive.latency(bs, cs) - truth.latency(bs, cs)).mean()
    assert err_r < err_n, "RANSAC must beat naive lstsq under outliers"
    assert err_r / truth.latency(8, 8) < 0.15


def test_throughput_definition():
    pm = yolov5s_like()
    assert pm.throughput(8, 8) == pytest.approx(8 / pm.latency(8, 8))
