import os

# Tests run on the single real CPU device; ONLY dryrun.py gets 512 fake
# devices.  Multi-device tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: F401  -- imported early so the platform pin above sticks
import numpy as np
import pytest

# Hypothesis profiles (optional dependency — see tests/_hyp.py):
# "default" keeps CI's per-push runs cheap; "deep" is the scheduled
# nightly sweep (.github/workflows/ci.yml sets HYPOTHESIS_PROFILE=deep).
# Tests that pin max_examples via @settings(...) keep their own budget.
try:
    from hypothesis import HealthCheck, settings as _hyp_settings

    _hyp_settings.register_profile("default", max_examples=50,
                                   deadline=None)
    _hyp_settings.register_profile(
        "deep", max_examples=1000, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE",
                                              "default"))
except ImportError:
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
