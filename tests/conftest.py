import os

# Tests run on the single real CPU device; ONLY dryrun.py gets 512 fake
# devices.  Multi-device tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
