"""Vectorized batched-tick control plane (ISSUE 8 tentpole).

``VectorSimRunner`` must replay the ``FastSimRunner`` event stream
**bit-identically** — decision stream, violation buckets, report floats
and core-seconds — on every registered closed-world scenario, for every
policy family it accepts (memoized sponge with the batched
decision-lookup fast path, exact sponge, static), and at sub-second
adaptation ticks (the regime the vectorpath exists for).  The satellite
helpers it leans on are held to the same bar: the tick-granular λ
estimator against the per-arrival ``RateEstimator``, and the memo
solver's batch ``solve_many`` against sequential ``solve`` calls.
"""
import numpy as np
import pytest

from repro.core.baselines import SpongePolicy, StaticPolicy
from repro.core.monitor import RateEstimator, tick_window_rate
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision
from repro.core.solver import DEFAULT_B, DEFAULT_C, MemoizedSolver
from repro.serving.fastpath import FastSimRunner
from repro.serving.scenarios import build_scenario, run_scenario
from repro.serving.vectorpath import VectorSimRunner

PERF = yolov5s_like()
PLAIN = ["steady", "diurnal", "flash-crowd", "network-replay", "mixed-slo"]


def _policy(kind, tick):
    if kind == "memo":
        return SpongePolicy(SpongeScaler(
            PERF, solver="memo", adaptation_interval=tick,
            budget_quantum=0.01, lam_quantum=0.5))
    if kind == "exact":
        return SpongePolicy(SpongeScaler(PERF, adaptation_interval=tick))
    return StaticPolicy(PERF, cores=16, b_set=DEFAULT_B, interval=tick)


def _runner(cls, kind, tick, prior):
    return cls(_policy(kind, tick), PERF, DEFAULT_C, DEFAULT_B, c0=16,
               tick=tick, prior_rps=prior)


def _sig(rep, runner):
    """Everything the equivalence contract covers, floats unrounded."""
    decs = [(t, d.c, d.b, getattr(d, "n", 1), d.feasible)
            for t, d in (rep.decisions or [])]

    def f(x):
        return "nan" if isinstance(x, float) and np.isnan(x) else x
    return (decs, runner.bucket_log, runner.core_samples,
            rep.core_seconds, rep.n_violations, rep.violation_rate,
            rep.avg_cores, f(rep.p50), f(rep.p99), rep.buckets)


def _both(batch, meta, kind, tick):
    prior = meta.get("rps") or meta.get("expected_rps") or 20.0
    fast = _runner(FastSimRunner, kind, tick, prior)
    vec = _runner(VectorSimRunner, kind, tick, prior)
    return (_sig(fast.run(batch), fast), _sig(vec.run(batch), vec))


@pytest.mark.parametrize("name", PLAIN)
@pytest.mark.parametrize("kind", ["memo", "exact", "static"])
def test_bit_identical_to_fastpath(name, kind):
    """The headline contract on every registered plain scenario."""
    batch, meta = build_scenario(name, duration=60, seed=11)
    f, v = _both(batch, meta, kind, meta.get("tick") or 1.0)
    assert f == v


@pytest.mark.parametrize("tick", [0.25, 0.1, 0.05])
def test_bit_identical_at_subsecond_ticks(tick):
    """The benchmark regime: sub-second adaptation cadence, memoized
    solver, batched decision lookups on the hot path."""
    batch, meta = build_scenario("steady", duration=45, seed=7)
    f, v = _both(batch, meta, "memo", tick)
    assert f == v


def test_bit_identical_nonmono_deadline_merge():
    """mixed-slo interleaves deadlines (the argsort + searchsorted +
    insert merge path, not the append path) — order must still match
    the heap's (deadline, handle) pop order exactly."""
    batch, meta = build_scenario("mixed-slo", duration=90, seed=3)
    assert np.any(np.diff(np.asarray(batch.deadline)) < 0), \
        "scenario must exercise the non-monotone merge"
    f, v = _both(batch, meta, "memo", 0.25)
    assert f == v


def test_two_runs_identical_and_engine_routing():
    """engine='vector' routes through run_scenario and is run-to-run
    deterministic; its report matches engine='fast' bit-for-bit."""
    kw = dict(duration=45, seed=11)
    r1, s1 = run_scenario("steady", engine="vector", **kw)
    r2, s2 = run_scenario("steady", engine="vector", **kw)
    rf, _ = run_scenario("steady", engine="fast", **kw)
    assert s1["engine"] == "vector"
    for a, b in ((r1, r2), (r1, rf)):
        assert [(t, d.c, d.b) for t, d in a.decisions] == \
            [(t, d.c, d.b) for t, d in b.decisions]
        assert (a.buckets, a.n_violations, a.core_seconds) == \
            (b.buckets, b.n_violations, b.core_seconds)


@pytest.mark.parametrize("name", ["llm-chat", "fleet-flash-crowd",
                                  "mixed-zoo"])
def test_vector_engine_rejects_non_plain_scenarios(name):
    """Token, fleet and multi-tenant scenarios need their own engines —
    engine='vector' must refuse loudly, not silently misreplay."""
    with pytest.raises(ValueError, match="vector"):
        run_scenario(name, engine="vector", duration=30, seed=1)


def test_vectorized_adapter_matches():
    """FastSimRunner.vectorized() hands its exact configuration (policy
    object included, so hand over *before* running either engine) to a
    fresh vector runner that replays identically to a fast run."""
    batch, meta = build_scenario("steady", duration=45, seed=5)
    donor = _runner(FastSimRunner, "memo", 1.0, meta["rps"])
    vec = donor.vectorized()
    assert (vec.tick, vec.c_set, vec.b_set) == \
        (donor.tick, donor.c_set, donor.b_set)
    fast = _runner(FastSimRunner, "memo", 1.0, meta["rps"])
    f = fast.run(batch)
    v = vec.run(batch)
    assert _sig(f, fast) == _sig(v, vec)


def test_explicit_horizon_and_empty_batch():
    batch, meta = build_scenario("steady", duration=40, seed=2)
    fast = _runner(FastSimRunner, "memo", 1.0, meta["rps"])
    vec = _runner(VectorSimRunner, "memo", 1.0, meta["rps"])
    assert _sig(fast.run(batch, horizon=25.0), fast) == \
        _sig(vec.run(batch, horizon=25.0), vec)
    empty = batch.head(0)
    rep = _runner(VectorSimRunner, "memo", 1.0, 20.0).run(empty)
    assert rep.n_requests == 0 and rep.n_violations == 0


def test_horizontal_policy_rejected():
    """Decision.n > 1 (FA2-style horizontal targets) is out of scope."""
    class Horizontal:
        decisions = None

        def due(self, now):
            return True

        def decide(self, now, queue, lam, initial_wait=0.0):
            return Decision(c=8, b=8, feasible=True, n=2)

    batch, _ = build_scenario("steady", duration=10, seed=1)
    vec = VectorSimRunner(Horizontal(), PERF, DEFAULT_C, DEFAULT_B, c0=16)
    with pytest.raises(NotImplementedError, match="horizontal"):
        vec.run(batch)


def test_events_processed_counts_control_events():
    batch, meta = build_scenario("steady", duration=30, seed=9)
    vec = _runner(VectorSimRunner, "memo", 1.0, meta["rps"])
    vec.run(batch)
    n_batches = len(vec.bucket_log)
    n_ticks = len(vec.core_samples)
    assert vec.events_processed == len(batch) + n_ticks + n_batches


def test_queue_mirror_stays_in_sync():
    """The Python-float mirror that feeds the front-cache key must
    track the live array region through appends, in-place inserts,
    merges and batch pops."""
    batch, meta = build_scenario("mixed-slo", duration=60, seed=13)
    vec = _runner(VectorSimRunner, "memo", 0.5, meta["expected_rps"])
    vec.run(batch)
    assert vec._q_dll == vec._q_dl[vec._qh:vec._qt].tolist()


# -- satellite: tick-granular λ ------------------------------------------
def test_tick_window_rate_matches_rate_estimator():
    """The estimator the runners now query at tick granularity equals
    the per-arrival RateEstimator at every tick time, on arrival
    streams with idle gaps, bursts and a deploy prior."""
    rng = np.random.default_rng(4)
    arr = np.sort(rng.uniform(0.0, 30.0, 400))
    arr = np.concatenate([arr, np.sort(45.0 + rng.uniform(0, 5, 50))])
    for prior in (0.0, 15.0):
        est = RateEstimator(window_s=2.0, prior_rps=prior)
        w0 = 0
        k = 0
        for now in np.arange(0.0, 55.0, 0.25):
            while k < arr.size and arr[k] <= now:
                est.observe(float(arr[k]))
                k += 1
            lam_obj = est.rate(float(now))
            lam_arr, w0 = tick_window_rate(arr, w0, float(now), 2.0,
                                           prior)
            assert lam_obj == lam_arr, (now, prior)


# -- satellite: batched decision lookups ---------------------------------
def test_solve_many_elementwise_identical():
    rng = np.random.default_rng(8)
    solver = MemoizedSolver(PERF, DEFAULT_C, DEFAULT_B,
                            budget_quantum=0.01, lam_quantum=0.5)
    seq = MemoizedSolver(PERF, DEFAULT_C, DEFAULT_B,
                         budget_quantum=0.01, lam_quantum=0.5)
    rems = [np.sort(rng.uniform(0.0, 1.0, rng.integers(0, 12)))
            for _ in range(60)]
    lams = rng.uniform(1.0, 40.0, 60)
    iws = rng.uniform(0.0, 0.4, 60)
    batch = solver.solve_many(rems, lams, iws)
    single = [seq.solve(r, float(l), initial_wait=float(w))
              for r, l, w in zip(rems, lams, iws)]
    assert [(d.c, d.b, d.feasible) for d in batch] == \
        [(d.c, d.b, d.feasible) for d in single]
    assert solver.hits + solver.misses == 60
