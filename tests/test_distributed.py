"""Multi-device integration tests (subprocess with 8 fake CPU devices).

The main pytest process keeps 1 device; these tests spawn a fresh python
with XLA_FLAGS=--xla_force_host_platform_device_count=8 and check that the
sharded program (a) compiles+runs and (b) matches the single-device result —
the strongest SPMD-correctness property available without hardware.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.models import build_model
from repro.models.api import init_params
from repro.models.sharding import param_specs, batch_specs, shardings
from repro.data import make_batch
from repro.train.loop import make_train_step, init_state
from repro.train.optimizer import OptConfig

arch = %(arch)r
cfg = get_config(arch, reduced=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))

B, S = 4, 16
batch = make_batch(cfg, B, S + (cfg.num_patch_tokens or 0), 0)
oc = OptConfig(lr=1e-3)

# single-device reference
m1 = build_model(cfg)
s1 = init_state(m1, jax.random.key(0), oc).as_dict()
_, met1 = jax.jit(make_train_step(m1, oc))(s1, batch)

# sharded
with mesh:
    m2 = build_model(cfg, mesh=mesh)
    s2 = init_state(m2, jax.random.key(0), oc).as_dict()
    pspecs = param_specs(jax.eval_shape(lambda: init_params(jax.random.key(0), cfg)), mesh)
    sspecs = {"params": pspecs, "opt": {"mu": pspecs, "nu": pspecs,
              "step": jax.sharding.PartitionSpec()}}
    sshard = shardings(sspecs, mesh)
    s2 = jax.device_put(s2, sshard)
    bshard = shardings(batch_specs(batch, mesh), mesh)
    batch2 = jax.device_put(batch, bshard)
    step = jax.jit(make_train_step(m2, oc), in_shardings=(sshard, bshard))
    _, met2 = step(s2, batch2)

print(json.dumps({"loss1": float(met1["loss"]), "loss2": float(met2["loss"]),
                  "g1": float(met1["grad_norm"]), "g2": float(met2["grad_norm"])}))
"""


def run_sharded(arch: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT % {"arch": arch}],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-v3-671b",
                                  "zamba2-2.7b", "rwkv6-1.6b"])
def test_sharded_train_step_matches_single_device(arch):
    r = run_sharded(arch)
    assert abs(r["loss1"] - r["loss2"]) < 0.05, r
    assert abs(r["g1"] - r["g2"]) / max(r["g1"], 1e-6) < 0.15, r
