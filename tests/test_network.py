"""Network trace + comm-latency model properties (paper Fig. 1)."""
import numpy as np
from _hyp import given, settings, st  # guarded hypothesis import

from repro.network.latency import comm_latency
from repro.network.traces import BandwidthTrace, synth_4g_trace


def test_trace_matches_paper_envelope():
    tr = synth_4g_trace(600, seed=0)
    assert len(tr.mbps) == 600
    assert tr.mbps.min() >= 0.5 - 1e-9
    assert tr.mbps.max() <= 7.0 + 1e-9
    # variability: the paper shows order-of-magnitude swings in 10 min
    assert tr.mbps.max() / tr.mbps.min() > 3.0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_trace_seeds_deterministic(seed):
    a = synth_4g_trace(120, seed=seed)
    b = synth_4g_trace(120, seed=seed)
    np.testing.assert_array_equal(a.mbps, b.mbps)


def test_short_trace_no_crash():
    tr = synth_4g_trace(5, seed=1)
    assert len(tr.mbps) == 5


@given(st.floats(10, 1000), st.floats(0, 700))
@settings(max_examples=50, deadline=None)
def test_comm_latency_monotone_in_size(kb, t):
    tr = synth_4g_trace(720, seed=3)
    assert comm_latency(kb * 2, tr, t) > comm_latency(kb, tr, t)


def test_comm_latency_paper_examples():
    """Fig 1: at 0.5 MB/s a 500 KB payload takes ~1 s."""
    tr = BandwidthTrace(t=np.arange(10.0), mbps=np.full(10, 0.5))
    cl = comm_latency(500, tr, 0.0)
    assert 0.9 < cl < 1.1
    tr7 = BandwidthTrace(t=np.arange(10.0), mbps=np.full(10, 7.0))
    assert comm_latency(100, tr7, 0.0) < 0.05
