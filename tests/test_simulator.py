"""Discrete-event simulator invariants + the paper's headline claims."""
import pytest

from repro.core.baselines import FA2Policy, SpongePolicy, StaticPolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.network.traces import synth_4g_trace
from repro.serving.simulator import ClusterSimulator
from repro.serving.workload import WorkloadGenerator

PERF = yolov5s_like()


def run_policy(policy, trace, rps=20, c0=1, duration=None):
    wl = WorkloadGenerator(rps=rps, slo=1.0, size_kb=200)
    sim = ClusterSimulator(PERF, policy, DEFAULT_C, DEFAULT_B, c0=c0)
    sim.monitor.rate.prior_rps = rps
    res = sim.run(wl.generate(trace, duration))
    return sim, res


@pytest.fixture(scope="module")
def trace():
    return synth_4g_trace(120, seed=7)


def test_request_lifecycle_invariants(trace):
    sim, res = run_policy(SpongePolicy(SpongeScaler(PERF)), trace, c0=16)
    assert res["n_requests"] > 0
    for r in sim.monitor.completed:
        assert r.start_proc is not None and r.finish is not None
        assert r.start_proc >= r.arrival - 1e-9, "served before arrival"
        assert r.finish > r.start_proc, "zero/negative processing time"


def test_every_request_served_exactly_once(trace):
    sim, res = run_policy(SpongePolicy(SpongeScaler(PERF)), trace, c0=16)
    ids = [r.id for r in sim.monitor.completed]
    assert len(ids) == len(set(ids))
    assert res["n_requests"] == len(ids)


def test_core_seconds_accounting(trace):
    sim, res = run_policy(StaticPolicy(PERF, cores=8), trace, c0=8)
    horizon = max(r.arrival for r in sim.monitor.completed) + 60.0
    # static allocation: core-seconds == 8 * elapsed
    assert res["core_seconds"] == pytest.approx(8 * horizon, rel=0.05)


def test_sponge_resizes_happen(trace):
    sim, res = run_policy(SpongePolicy(SpongeScaler(PERF)), trace, c0=16)
    inst = sim.pool[0].instance
    assert len(inst.resizes) > 3, "vertical scaling never engaged"
    cs = {e.c_to for e in inst.resizes}
    assert len(cs) > 1


def test_fa2_cold_start_delay(trace):
    sim, res = run_policy(
        FA2Policy(PERF, slo=1.0, expected_rps=20, cold_start=10.0),
        trace)
    started = [s for s in sim.pool if s.ready_at > 0]
    for s in started:
        assert s.ready_at - s.alive_since >= 10.0 - 1e-9


@pytest.mark.slow
def test_paper_headline_claims():
    """Fig. 4: sponge <0.5% violations, >=10x better than FA2, >=15% fewer
    cores than static-16 (paper: <0.3%, >15x, >20% on its testbed; the
    slight slack absorbs trace-seed variance)."""
    trace = synth_4g_trace(600, seed=42)
    _, sp = run_policy(SpongePolicy(SpongeScaler(PERF)), trace, c0=16)
    _, fa = run_policy(FA2Policy(PERF, slo=1.0, expected_rps=20), trace)
    _, s8 = run_policy(StaticPolicy(PERF, cores=8), trace, c0=8)
    _, s16 = run_policy(StaticPolicy(PERF, cores=16), trace, c0=16)
    assert sp["violation_rate"] < 0.005
    assert fa["violation_rate"] > 10 * sp["violation_rate"]
    assert s8["violation_rate"] > 0.5, "static-8 must be under-provisioned"
    assert s16["violation_rate"] < 0.005
    saving = 1 - sp["avg_cores"] / s16["avg_cores"]
    assert saving > 0.15


def test_edf_priority_under_pressure():
    """With a starved server, tighter-deadline requests finish first."""
    from repro.core.slo import Request
    sim = ClusterSimulator(PERF, StaticPolicy(PERF, cores=1), (1,),
                           DEFAULT_B, c0=1)
    reqs = [Request.make(arrival=1.0, comm_latency=0.01 * i, slo=1.0 + 0.1 * i)
            for i in range(10)]
    # occupy the server so all requests queue together before dispatch
    sim.pool[0].busy_until = 2.0
    sim.run(list(reversed(reqs)), horizon=30)
    # EDF: every request in an earlier batch (finish time group) has a
    # deadline <= every request in a later batch
    groups: dict = {}
    for r in sim.monitor.completed:
        groups.setdefault(r.finish, []).append(r.deadline)
    fins = sorted(groups)
    for a, b in zip(fins, fins[1:]):
        assert max(groups[a]) <= min(groups[b]) + 1e-9
