"""Fleet layer (ISSUE 4): decision identity, joint solver, drain, routers.

The acceptance contract: ``FleetFastSimRunner`` (struct-of-arrays) and
``FleetExactRunner`` (the pre-heaped exact gang loop) produce identical
``(n, c, b)`` decision streams, batch buckets and aggregate results on
the fleet scenarios — the same oracle discipline ``tests/test_fastpath``
applies to the single-replica engines.  Plus unit coverage for the joint
solver (bruteforce == table == memo; the n_set=(1,) reduction to
Algorithm 1), hysteresis, scale-down drain and the routers.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.perf_model import yolov5s_like
from repro.core.solver import (DEFAULT_B, DEFAULT_C, JointMemoizedSolver,
                               JointSolverTable, joint_candidates,
                               solve_bruteforce, solve_joint_bruteforce)
from repro.network.traces import synth_4g_trace
from repro.serving.fleet import (ROUTERS, FleetExactRunner,
                                 FleetFastSimRunner, FleetSpongeScaler,
                                 StaticFleetPolicy)
from repro.serving.scenarios import build_scenario
from repro.serving.workload import WorkloadGenerator

PERF = yolov5s_like()
N_SET = (1, 2, 3, 4, 6, 8, 12, 16)
FLEET_SCENARIOS = ("replica-failure", "rolling-restart",
                   "fleet-flash-crowd")


def _sig(report):
    """Everything that must match across the two fleet engines."""
    decisions = [(t, d.c, d.b, d.n, d.scale_up_delay, d.feasible)
                 for t, d in (report.decisions or [])]
    return (decisions, report.buckets, report.n_requests,
            report.n_violations, report.core_seconds, report.p50,
            report.p99, report.core_timeline)


def _scaler(**kw):
    return FleetSpongeScaler(PERF, adaptation_interval=0.5, **kw)


# --------------------------------------------------------------------------
# the acceptance bar: fleet decision identity on the fleet scenarios
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name", FLEET_SCENARIOS)
def test_fleet_decision_identity_on_scenarios(name):
    """Fast engine == exact gang loop, decision for decision, on every
    registered fleet scenario (disruption events included)."""
    batch, meta = build_scenario(name, duration=90, seed=7)
    kw = dict(n0=meta["n0"], c0=meta["c0"], tick=meta["tick"],
              prior_rps=meta["expected_rps"], router=meta["router"])
    fast = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B, **kw)
    exact = FleetExactRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B, **kw)
    got = fast.run(batch, events=meta["fleet_events"])
    ref = exact.run(batch, events=meta["fleet_events"])
    assert _sig(got) == _sig(ref)
    assert got.n_requests > 0


@pytest.mark.parametrize("router", ROUTERS)
def test_fleet_decision_identity_across_routers(router):
    """Identity holds for every router, with kill + restart events."""
    trace = synth_4g_trace(100, seed=3)
    wl = WorkloadGenerator(rps=60, slo=1.0, size_kb=200, poisson=True,
                           seed=3)
    batch = wl.generate_batch(trace, 80)
    events = ((25.0, "kill", 1), (50.0, "restart", 0, 4.0))
    kw = dict(n0=4, c0=16, prior_rps=60, router=router)
    fast = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B, **kw)
    exact = FleetExactRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B, **kw)
    assert _sig(fast.run(batch, events=events)) == \
        _sig(exact.run(batch, events=events))


def test_fleet_identity_with_static_policy():
    """The static-fleet baseline is engine-identical too."""
    trace = synth_4g_trace(80, seed=5)
    wl = WorkloadGenerator(rps=40, slo=1.0, size_kb=200, poisson=True,
                           seed=5)
    batch = wl.generate_batch(trace, 60)
    kw = dict(n0=4, c0=8, prior_rps=40)

    def pol():
        return StaticFleetPolicy(PERF, replicas=4, cores=8)

    fast = FleetFastSimRunner(pol(), PERF, DEFAULT_C, DEFAULT_B, **kw)
    exact = FleetExactRunner(pol(), PERF, DEFAULT_C, DEFAULT_B, **kw)
    assert _sig(fast.run(batch)) == _sig(exact.run(batch))


# --------------------------------------------------------------------------
# joint solver: bruteforce == table == memo, and the n=1 reduction
# --------------------------------------------------------------------------
budgets = st.lists(st.floats(0.05, 3.0), min_size=0, max_size=40)
lams = st.floats(0.0, 300.0)
waits = st.floats(0.0, 0.5)


@given(budgets, lams, waits)
@settings(deadline=None)
def test_joint_table_agrees_with_bruteforce(rem, lam, wait):
    """The precomputed joint grid is the joint Algorithm 1, vectorized."""
    tab = JointSolverTable(PERF, n_set=N_SET)
    d1 = solve_joint_bruteforce(rem, lam, PERF, n_set=N_SET,
                                initial_wait=wait)
    d2 = tab.solve(rem, lam, initial_wait=wait)
    assert (d1.c, d1.b, d1.n, d1.feasible) == (d2.c, d2.b, d2.n,
                                               d2.feasible)


@given(budgets, lams, waits)
@settings(deadline=None)
def test_joint_reduces_to_algorithm1_at_n1(rem, lam, wait):
    """n_set=(1,) degenerates to the paper's single-replica Algorithm 1
    decision for decision — the joint solver is a strict extension."""
    d1 = solve_bruteforce(rem, lam, PERF, initial_wait=wait)
    d2 = solve_joint_bruteforce(rem, lam, PERF, n_set=(1,),
                                initial_wait=wait)
    assert (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)
    assert d2.n == 1


def test_joint_solver_fuzz_without_hypothesis():
    """Seeded fuzz of bruteforce == table == memo (+ the only_n pin),
    kept independent of hypothesis availability."""
    tab = JointSolverTable(PERF, n_set=N_SET)
    memo = JointMemoizedSolver(PERF, n_set=N_SET)
    rng = np.random.default_rng(0)
    for _ in range(150):
        n = int(rng.integers(0, 40))
        rem = np.sort(rng.uniform(0.0, 3.0, n))
        lam = float(rng.uniform(0, 300))
        iw = float(rng.uniform(0, 0.5))
        d1 = solve_joint_bruteforce(rem, lam, PERF, n_set=N_SET,
                                    initial_wait=iw)
        d2 = tab.solve(rem, lam, initial_wait=iw)
        d3 = memo.solve(rem, lam, initial_wait=iw)
        key = (d1.c, d1.b, d1.n, d1.feasible)
        assert key == (d2.c, d2.b, d2.n, d2.feasible)
        assert key == (d3.c, d3.b, d3.n, d3.feasible)
        dp = tab.solve(rem, lam, initial_wait=iw, only_n=4)
        db = solve_joint_bruteforce(rem, lam, PERF, n_set=(4,),
                                    initial_wait=iw)
        assert (dp.c, dp.b, dp.n, dp.feasible) == (db.c, db.b, db.n,
                                                   db.feasible)


def test_joint_candidate_order_minimizes_total_cores():
    """The search order is total allocation ascending, so any feasible
    answer is the cheapest one; the replica penalty reorders wide fleets
    behind tall ones at equal cores."""
    cands = joint_candidates((1, 2, 4), (1, 2), (1, 2, 4))
    totals = [t for t, _, _, _ in cands]
    assert totals == sorted(totals)
    # pure objective: 4 replicas x 1 core ties 1 replica x 4 cores; the
    # tie breaks toward fewer replicas
    t4 = [(n, c) for t, n, b, c in cands if t == 4]
    assert t4[0][0] == 1
    pen = joint_candidates((1, 2, 4), (1,), (1, 2, 4), replica_pen=0.5)
    keys = [t for t, _, _, _ in pen]
    assert keys == sorted(keys)
    assert pen[0][1:] == (1, 1, 1)      # n=1, b=1, c=1 still first


def test_joint_solver_prefers_fewer_replicas_on_cost_ties():
    """With an empty queue and tiny λ the cheapest allocation is one
    1-core replica — never a wide fleet of the same total size."""
    d = solve_joint_bruteforce([], 0.5, PERF, n_set=N_SET)
    assert (d.n, d.c) == (1, 1) and d.feasible


def test_joint_solver_scales_out_when_vertical_saturates():
    """A λ beyond one replica's max throughput forces n > 1."""
    lam_max = float(max(PERF.throughput(b, max(DEFAULT_C))
                        for b in DEFAULT_B))
    d = solve_joint_bruteforce([], lam_max * 2.5, PERF, n_set=N_SET)
    assert d.feasible and d.n > 1
    assert d.n * float(PERF.throughput(d.b, d.c)) >= lam_max * 2.5


# --------------------------------------------------------------------------
# hysteresis + scale-down drain semantics
# --------------------------------------------------------------------------
def test_hysteresis_blocks_transient_scale_down():
    """A lower-n target must persist ``down_patience`` decisions before
    the fleet shrinks; in the meantime (c, b) re-solves at the pinned n."""
    sc = FleetSpongeScaler(PERF, down_patience=3, scale_up_delay=0.0)
    rem = np.empty(0)
    # active fleet of 8; the solver wants 1 replica at this load
    for i in range(2):
        d = sc.decide_fleet(float(i), rem, 2.0, active_n=8)
        assert d.n == 8, "scale-down emitted before patience ran out"
    d = sc.decide_fleet(2.0, rem, 2.0, active_n=8)
    assert d.n < 8, "scale-down never emitted"
    # an up-target resets the streak
    sc2 = FleetSpongeScaler(PERF, down_patience=2, scale_up_delay=0.0)
    sc2.decide_fleet(0.0, rem, 2.0, active_n=8)
    lam_big = 2.5 * float(max(PERF.throughput(b, max(DEFAULT_C))
                              for b in DEFAULT_B))
    d_up = sc2.decide_fleet(1.0, rem, lam_big, active_n=1)
    assert d_up.n > 1
    assert sc2._down_streak == 0


def test_hysteresis_pin_survives_sparse_n_set():
    """After a kill event active_n can sit outside a sparse n_set; the
    blocked-scale-down re-solve must pin to a *valid* entry (rounding
    down — conservative) and still hold the actual fleet size, not fall
    into the infeasible max-capacity branch."""
    sc = FleetSpongeScaler(PERF, n_set=(1, 2, 4, 8, 16), down_patience=3,
                           scale_up_delay=0.0)
    d = sc.decide_fleet(0.0, np.empty(0), 2.0, active_n=7)
    assert d.n == 7, "fleet size not held during hysteresis"
    assert d.feasible, "pinned re-solve fell into the infeasible fallback"
    assert d.c < max(DEFAULT_C), "light load must not pin max capacity"


def test_scale_down_drains_before_releasing_cores():
    """A retiring replica stops admitting, finishes in-flight work, and
    releases cores at max(now, busy_until); its queue re-routes."""
    runner = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B,
                                n0=4, c0=8, prior_rps=10)
    # give the soon-to-retire replica queued work and an in-flight batch
    victim = runner.replicas[-1]
    victim.busy_until = 12.5
    victim.queue.push(20.0, 0)
    victim.queue.push(21.0, 1)
    from repro.core.slo import Decision
    runner._apply(Decision(c=8, b=4, n=2), now=10.0)
    assert len(runner.replicas) == 2
    assert victim in runner.dead
    assert victim.dead_at == 12.5          # finishes in-flight work first
    assert len(victim.queue._heap) == 0    # queue re-routed
    moved = sum(len(r.queue._heap) for r in runner.replicas)
    assert moved == 2
    # core-second accounting runs to the release point, not beyond
    victim.account(100.0)                  # report clamps to dead_at
    rep_end = min(victim.dead_at, 100.0)
    assert victim._last_t >= rep_end


def test_fleet_never_scales_to_zero():
    runner = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B,
                                n0=2, c0=8)
    from repro.core.slo import Decision
    runner._apply(Decision(c=8, b=1, n=0), now=0.0)
    assert len(runner.replicas) == 1
    runner._fleet_event("kill", (0,), 1.0)
    assert len(runner.replicas) == 1, "the last replica must survive kills"


def test_restart_event_spawns_cold_replacement():
    runner = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B,
                                n0=3, c0=8)
    old = runner.replicas[0]
    runner._fleet_event("restart", (0, 4.0), 10.0)
    assert len(runner.replicas) == 3
    assert old in runner.dead
    fresh = runner.replicas[-1]
    assert fresh.ready_at == 14.0 and fresh.c == old.c


# --------------------------------------------------------------------------
# routers
# --------------------------------------------------------------------------
def _push(rep, deadline, idx):
    """Push the way the runners do: heap + sorted deadline mirror."""
    from bisect import insort
    rep.queue.push(deadline, idx)
    insort(rep.dls, deadline)


def test_routers_balance_and_respect_cold_starts():
    from repro.serving.fleet import route_request
    runner = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B,
                                n0=3, c0=8)
    a, b, c = runner.replicas
    _push(a, 5.0, 0)
    _push(a, 6.0, 1)
    _push(b, 5.5, 2)
    # jsq: shortest queue wins (c is empty)
    assert route_request("jsq", runner.replicas, 7.0, 0.0) == 2
    # least-loaded: busy penalty breaks the tie toward the idle replica
    _push(c, 5.9, 3)
    b.busy_until = 1.0
    assert route_request("least-loaded", runner.replicas, 7.0, 0.0) == 2
    # edf-deadline: join where the fewest earlier deadlines sit ahead
    assert route_request("edf-deadline", runner.replicas, 5.2, 0.0,
                         ) == 1  # b has 0 earlier than 5.2 among (5.5,)
    # cold replicas only attract work once warm queues are deeper: c has
    # the shortest queue but 10 s of boot left, so the load tie between
    # a (2 queued) and b (1 queued + busy) resolves to the lower index
    cold = runner._cold_load(0.0)
    c.ready_at = 10.0
    assert route_request("least-loaded", runner.replicas, 7.0, 0.0,
                         cold_load=cold) == 0
    assert cold(c) > 10.0 and cold(a) == 0.0
    with pytest.raises(KeyError):
        route_request("no-such-router", runner.replicas, 1.0, 0.0)


def test_deadline_mirror_tracks_queues_mid_backlog():
    """The sorted deadline mirror the edf-deadline router bisects must
    equal the live heap contents at any stop point — checked by cutting
    a fleet-flash-crowd run mid-spike, when queues hold real backlog."""
    batch, meta = build_scenario("fleet-flash-crowd", duration=120, seed=3)
    runner = FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B,
                                n0=meta["n0"], c0=meta["c0"],
                                tick=meta["tick"],
                                prior_rps=meta["expected_rps"],
                                router="edf-deadline")
    runner.run(batch, horizon=0.42 * 120)      # inside the first spike
    backlog = 0
    for r in runner.replicas:
        heap_dls = sorted(item[0] for item in r.queue._heap)
        assert r.dls == heap_dls
        backlog += len(heap_dls)
    assert backlog > 0, "expected queued work mid-spike"


def test_unknown_router_and_policy_rejected():
    with pytest.raises(KeyError):
        FleetFastSimRunner(_scaler(), PERF, DEFAULT_C, DEFAULT_B,
                           router="bogus")

    class NotAFleetPolicy:
        pass

    with pytest.raises(TypeError):
        FleetFastSimRunner(NotAFleetPolicy(), PERF, DEFAULT_C, DEFAULT_B)


# --------------------------------------------------------------------------
# end-to-end economics (small-scale preview of benchmarks/fleet_bench.py)
# --------------------------------------------------------------------------
def test_fleet_saves_cores_vs_static_at_no_worse_violations():
    """The joint scaler must beat the peak-provisioned static fleet on
    core-seconds without losing on violation rate (the bench bar at
    small scale)."""
    from repro.serving.scenarios import run_scenario
    sponge, stats = run_scenario("replica-failure", engine="fast",
                                 duration=150, seed=7)
    static, _ = run_scenario("replica-failure", engine="fast",
                             policy="static-16", duration=150, seed=7)
    assert sponge.violation_rate <= static.violation_rate + 0.01
    assert sponge.core_seconds < 0.8 * static.core_seconds
    assert stats["max_replicas"] >= 4


def test_fleet_scenarios_registered_and_routed():
    from repro.serving.scenarios import SCENARIOS
    for name in FLEET_SCENARIOS:
        assert name in SCENARIOS
        batch, meta = build_scenario(name, duration=60, seed=1)
        assert meta["fleet"] is True and len(batch) > 0
        assert meta["n0"] >= 4 and meta["router"] in ROUTERS
