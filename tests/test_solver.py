"""Property tests (hypothesis) for the IP solver — the paper's Algorithm 1."""
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.perf_model import PerfModel, yolov5s_like
from repro.core.solver import (DEFAULT_B, DEFAULT_C, solve_bruteforce,
                               solve_pruned, TPU_C)

PERF = yolov5s_like()

budgets = st.lists(st.floats(0.05, 3.0), min_size=0, max_size=40)
lams = st.floats(0.0, 40.0)
waits = st.floats(0.0, 0.5)


def _feasible(rem, lam, c, b, perf, initial_wait=0.0):
    l = float(perf.latency(b, c))
    if lam > 0 and perf.throughput(b, c) < lam:
        return False
    rem = sorted(rem)
    q = initial_wait
    for i in range(0, len(rem), b):
        if l + q > rem[i] + 1e-9:
            return False
        q += l
    return True


@given(budgets, lams, waits)
@settings(deadline=None)
def test_bruteforce_returns_feasible_or_flags(rem, lam, wait):
    d = solve_bruteforce(rem, lam, PERF, initial_wait=wait)
    assert d.c in DEFAULT_C and d.b in DEFAULT_B
    if d.feasible:
        assert _feasible(rem, lam, d.c, d.b, PERF, wait)


@given(budgets, lams, waits)
@settings(deadline=None)
def test_bruteforce_minimality(rem, lam, wait):
    """Algorithm 1 returns the minimum feasible c (the IP optimum)."""
    d = solve_bruteforce(rem, lam, PERF, initial_wait=wait)
    if not d.feasible:
        return
    for c in DEFAULT_C:
        if c >= d.c:
            break
        for b in DEFAULT_B:
            assert not _feasible(rem, lam, c, b, PERF, wait), \
                f"(c={c},b={b}) feasible but solver returned c={d.c}"


@given(budgets, lams, waits)
@settings(deadline=None)
def test_pruned_agrees_with_bruteforce_on_c(rem, lam, wait):
    """The vectorized solver finds the same optimal c (it may pick a
    different b at equal cost only if delta_pen ties — same delta_pen here,
    so (c, b) must match exactly when both are feasible)."""
    d1 = solve_bruteforce(rem, lam, PERF, initial_wait=wait)
    d2 = solve_pruned(rem, lam, PERF, initial_wait=wait)
    assert d1.feasible == d2.feasible
    if d1.feasible:
        assert (d1.c, d1.b) == (d2.c, d2.b)


@given(budgets, lams)
@settings(deadline=None)
def test_more_budget_never_needs_more_cores(rem, lam):
    d1 = solve_bruteforce(rem, lam, PERF)
    d2 = solve_bruteforce([r + 1.0 for r in rem], lam, PERF)
    if d1.feasible:
        assert d2.feasible
        assert d2.c <= d1.c


def test_tpu_cset_is_subset_behaviour():
    rem = [0.5] * 10
    d = solve_bruteforce(rem, 20.0, PERF, c_set=TPU_C)
    assert d.c in TPU_C


def test_empty_queue_min_allocation():
    d = solve_bruteforce([], 0.0, PERF)
    assert d.feasible and d.c == 1 and d.b == 1


def test_throughput_constraint_binds():
    # lam high enough that c=1 cannot sustain it
    d = solve_bruteforce([10.0] * 4, 20.0, PERF)
    assert d.feasible
    assert PERF.throughput(d.b, d.c) >= 20.0


def test_paper_motivating_example():
    """Paper §2.1: with 600 ms of network delay and SLO 1000 ms, vertical
    scaling still finds a config (8 cores, batch 4 in Table 1's regime)."""
    perf = PerfModel.fit.__self__  # noqa — use table-1 fit below
    from repro.core.perf_model import fit_table1
    perf = fit_table1()
    remaining = [0.4] * 10           # SLO 1.0 minus 0.6 comm latency
    d = solve_bruteforce(remaining, 100.0, perf)
    assert d.feasible, "Table-1 model must serve 100RPS within 400ms budgets"
    assert d.c >= 4
    # while a 1-core-only system (FA2's world) cannot
    d1 = solve_bruteforce(remaining, 100.0, perf, c_set=(1,))
    assert not d1.feasible
