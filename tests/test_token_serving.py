"""Phase-aware autoregressive serving (ISSUE 3): token cost model,
token-composition solver, continuous-batching engines, scenarios, and
the satellite fixes (λ-estimator guard, shared decision resolution)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # guarded hypothesis import

from repro.core.cost_model import Composition, TokenCostModel
from repro.core.monitor import RateEstimator
from repro.core.perf_model import yolov5s_like
from repro.core.queueing import EDFQueue, TokenFastEDFQueue
from repro.core.scaler import TokenSpongeScaler
from repro.core.slo import Decision, Request
from repro.core.solver import (TokenMemoizedSolver, TokenSolverTable,
                               solve_token_bruteforce)
from repro.serving.api import (ScenarioRunner, TokenSimBackend,
                               resolve_decision)
from repro.serving.fastpath import FastSimRunner, TokenFastSimRunner
from repro.serving.workload import RequestBatch, lognormal_lengths

PERF = yolov5s_like()
COST = TokenCostModel.smollm_like()
C16 = tuple(range(1, 17))


def _token_batch(n=400, duration=40.0, seed=0, tbt=0.08):
    rng = np.random.default_rng(seed)
    send = np.sort(rng.uniform(0, duration, n))
    cl = rng.uniform(0.01, 0.15, n)
    pt = lognormal_lengths(rng, n, median=64, sigma=0.6, lo=8, hi=512)
    dt = lognormal_lengths(rng, n, median=24, sigma=0.5, lo=1, hi=128)
    return RequestBatch.from_send(send, cl, slo=1.0, prompt_tokens=pt,
                                  decode_tokens=dt, tbt_slo=tbt)


# --------------------------------------------------------------------------
# cost model
# --------------------------------------------------------------------------
def test_token_cost_model_surfaces_consistent():
    c = 4
    assert COST.step_latency(c, Composition(100, 0)) == pytest.approx(
        float(COST.prefill_latency(c, 100)))
    assert COST.step_latency(c, Composition(0, 8)) == pytest.approx(
        float(COST.decode_latency(c, 8)))
    assert COST.step_latency(c, Composition(0, 0)) == 0.0
    # mixed step shares one per-step overhead, so it is cheaper than the
    # two phases run separately
    mixed = COST.step_latency(c, Composition(100, 8))
    assert mixed < (float(COST.prefill_latency(c, 100))
                    + float(COST.decode_latency(c, 8)))
    # more cores never slower, more work never faster
    assert COST.decode_latency(2, 8) > COST.decode_latency(16, 8)
    assert COST.prefill_latency(4, 512) > COST.prefill_latency(4, 64)


def test_token_cost_model_fit_recovers_surface():
    pre, dec = COST.sample_profile([16, 64, 256, 1024], [1, 2, 4, 8, 16],
                                   [1, 2, 4, 8, 16], noise=0.0)
    fit = TokenCostModel.fit(pre, dec, mean_prompt=COST.mean_prompt,
                             mean_decode=COST.mean_decode)
    assert fit.r2_prefill > 0.999 and fit.r2_decode > 0.999
    for c in (1, 4, 16):
        assert float(fit.prefill_latency(c, 200)) == pytest.approx(
            float(COST.prefill_latency(c, 200)), rel=1e-3)
        assert float(fit.decode_latency(c, 12)) == pytest.approx(
            float(COST.decode_latency(c, 12)), rel=1e-3)


def test_prefill_token_allowance_inverts_step_latency():
    for c in (1, 4, 16):
        budget = 0.06
        allow = COST.prefill_token_allowance(c, 8, budget)
        assert allow > 0
        at = COST.step_latency(c, Composition(int(allow), 8))
        assert at <= budget + 1e-6
        over = COST.step_latency(c, Composition(int(allow) + 50, 8))
        assert over > budget
    assert COST.prefill_token_allowance(4, 8, float("inf")) == float("inf")


# --------------------------------------------------------------------------
# token solver: vectorized table == bruteforce reference
# --------------------------------------------------------------------------
def _random_solver_inputs(rng):
    n = int(rng.integers(0, 30))
    rem = np.sort(rng.uniform(0, 2.0, n))
    toks = rng.integers(1, 400, n).astype(np.float64)
    lam = float(rng.uniform(0, 60))
    iw = float(rng.uniform(0, 0.3))
    tbt = float(rng.choice([np.inf, 0.02, 0.05, 0.2]))
    act = int(rng.integers(0, 8))
    return rem, toks, lam, iw, tbt, act


def test_token_table_matches_bruteforce_fuzz():
    rng = np.random.default_rng(0)
    tab = TokenSolverTable(COST)
    for _ in range(400):
        rem, toks, lam, iw, tbt, act = _random_solver_inputs(rng)
        d1 = solve_token_bruteforce(rem, toks, lam, COST, initial_wait=iw,
                                    tbt_budget=tbt, active_slots=act)
        d2 = tab.solve(rem, toks, lam, initial_wait=iw, tbt_budget=tbt,
                       active_slots=act)
        assert (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)
        assert d1.predicted_tbt == pytest.approx(d2.predicted_tbt)


budgets = st.lists(st.floats(0.05, 3.0), min_size=0, max_size=24)


@given(budgets, st.floats(0.0, 40.0), st.floats(0.0, 0.4),
       st.floats(0.0, 0.25), st.integers(0, 123))
@settings(deadline=None)
def test_token_table_matches_bruteforce_property(rem, lam, wait, tbt,
                                                 tok_seed):
    rng = np.random.default_rng(tok_seed)
    toks = rng.integers(1, 600, len(rem)).astype(np.float64)
    tbt = tbt if tbt > 0.01 else float("inf")
    tab = TokenSolverTable(COST)
    d1 = solve_token_bruteforce(rem, toks, lam, COST, initial_wait=wait,
                                tbt_budget=tbt)
    d2 = tab.solve(rem, toks, lam, initial_wait=wait, tbt_budget=tbt)
    assert (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)


def test_token_solver_tbt_constraint_forces_scale_up():
    """A tight per-token budget must rule out low-core configs."""
    loose = TokenSolverTable(COST).solve([1.0], [64], 1.0,
                                         tbt_budget=float("inf"))
    tight = TokenSolverTable(COST).solve([1.0], [64], 1.0,
                                         tbt_budget=0.013)
    assert tight.c > loose.c
    assert tight.predicted_tbt <= 0.013


def test_token_solver_fixed_work_special_case():
    """Zero decode + unit prompts: TBT vacuous, TTFT drain is Algorithm 1
    with group latency = prefill of b one-token requests."""
    fw = TokenCostModel(gamma_p=COST.gamma_p, delta_p=COST.delta_p,
                        gamma_d=0.0, delta_d=0.0, eps=COST.eps,
                        eta=COST.eta, mean_prompt=1.0, mean_decode=0.0)
    d = solve_token_bruteforce([0.5, 0.7], [1, 1], 2.0, fw)
    assert d.feasible and d.predicted_tbt >= 0.0
    # no decode stream anywhere -> TBT budget is ignored entirely
    d2 = solve_token_bruteforce([0.5, 0.7], [1, 1], 2.0, fw,
                                tbt_budget=1e-9)
    assert (d.c, d.b) == (d2.c, d2.b)


def test_token_memo_exact_at_quantum_zero_and_conservative():
    rng = np.random.default_rng(7)
    tab = TokenSolverTable(COST)
    memo0 = TokenMemoizedSolver(COST)
    memoq = TokenMemoizedSolver(COST, budget_quantum=0.02,
                                lam_quantum=0.5, token_quantum=16)
    for _ in range(120):
        rem, toks, lam, iw, tbt, act = _random_solver_inputs(rng)
        exact = tab.solve(rem, toks, lam, initial_wait=iw, tbt_budget=tbt,
                          active_slots=act)
        z = memo0.solve(rem, toks, lam, initial_wait=iw, tbt_budget=tbt,
                        active_slots=act)
        assert (z.c, z.b, z.feasible) == (exact.c, exact.b, exact.feasible)
        q = memoq.solve(rem, toks, lam, initial_wait=iw, tbt_budget=tbt,
                        active_slots=act)
        if exact.feasible and q.feasible:
            assert q.c >= exact.c       # never an optimistic allocation
    assert memoq.misses <= memo0.misses


def test_token_memo_cache_hits():
    memo = TokenMemoizedSolver(COST, budget_quantum=0.01, lam_quantum=0.5,
                               token_quantum=16)
    for _ in range(5):
        memo.solve([0.5, 0.9], [100, 40], 12.3, initial_wait=0.01,
                   tbt_budget=0.08, active_slots=3)
    assert memo.misses == 1 and memo.hits == 4


# --------------------------------------------------------------------------
# request / queue token surfaces
# --------------------------------------------------------------------------
def test_request_token_fields_and_violation_semantics():
    r = Request.make(arrival=1.0, comm_latency=0.1, slo=1.0,
                     prompt_tokens=64, decode_tokens=10, tbt_slo=0.05)
    assert r.is_autoregressive and r.deadline == pytest.approx(1.9)
    r.first_token = 1.8
    r.finish = 5.0                      # late *completion* is fine
    assert not r.violated
    r.tbt_violations = 1                # one slow token is not
    assert r.violated
    fixed = Request.make(arrival=1.0, comm_latency=0.1, slo=1.0)
    fixed.finish = 5.0
    assert fixed.violated and not fixed.is_autoregressive


def test_queue_token_snapshots_agree():
    reqs = [Request.make(arrival=0.01, comm_latency=0.01, slo=s,
                         prompt_tokens=p, decode_tokens=4, tbt_slo=t)
            for s, p, t in ((1.0, 64, 0.08), (0.5, 32, 0.05),
                            (2.0, 400, 0.2))]
    q = EDFQueue()
    q.extend(reqs)
    rem, toks, tbt = q.token_snapshot(0.0)
    assert np.all(np.diff(rem) >= 0)
    assert toks.tolist() == [32, 64, 400]       # aligned to EDF order
    assert tbt == 0.05

    batch = RequestBatch.from_send(
        np.zeros(3), np.full(3, 0.01), slo=np.array([1.0, 0.5, 2.0]),
        prompt_tokens=np.array([64, 32, 400]),
        decode_tokens=np.full(3, 4), tbt_slo=np.array([0.08, 0.05, 0.2]))
    fq = TokenFastEDFQueue()
    fq.bind(batch.prompt_tokens, batch.tbt_slo)
    for i in range(3):
        fq.push(batch.deadline[i], i)
    frem, ftoks, ftbt = fq.token_snapshot(0.0)
    assert np.allclose(frem, rem) and ftoks.tolist() == toks.tolist()
    assert ftbt == tbt


def test_request_batch_token_columns_roundtrip():
    batch = _token_batch(n=50, seed=3)
    assert batch.total_tokens == int(batch.decode_tokens.sum()) + 50
    reqs = batch.to_requests()
    i = 25
    assert reqs[i].prompt_tokens == batch.prompt_tokens[i]
    assert reqs[i].decode_tokens == batch.decode_tokens[i]
    head = batch.head(10)
    assert len(head) == 10 and head.prompt_tokens.size == 10
    # defaults: a token-less batch is fixed work
    plain = RequestBatch.from_send(np.arange(5.0), np.full(5, 0.01),
                                   slo=1.0)
    assert plain.prompt_tokens.tolist() == [1] * 5
    assert plain.decode_tokens.tolist() == [0] * 5
    assert np.all(np.isinf(plain.tbt_slo))


# --------------------------------------------------------------------------
# continuous-batching engines
# --------------------------------------------------------------------------
def test_token_fast_runner_serves_everything():
    batch = _token_batch(n=600, duration=60.0, seed=1)
    scaler = TokenSpongeScaler(COST)
    runner = TokenFastSimRunner(scaler, COST, c0=16, prior_rps=10.0)
    rep = runner.run(batch)
    assert rep.n_requests == len(batch)
    assert rep.tokens_served == batch.total_tokens
    assert rep.backend == "token-sim-fast"
    assert np.isfinite(rep.ttft_p99) and rep.ttft_p99 > 0
    assert 0.0 <= rep.tbt_violation_rate <= 1.0
    assert rep.core_seconds > 0 and len(scaler.decisions) > 0
    assert rep.tokens_per_s > 0


def test_token_fast_runner_join_leave_semantics():
    """Two staggered requests share the decode stream: the second joins
    while the first is mid-stream and both finish in one busy period."""
    send = np.array([0.0, 0.05])
    cl = np.full(2, 0.01)
    batch = RequestBatch.from_send(send, cl, slo=5.0,
                                   prompt_tokens=np.array([32, 32]),
                                   decode_tokens=np.array([40, 5]),
                                   tbt_slo=np.inf)
    scaler = TokenSpongeScaler(COST, adaptation_interval=0.1)
    runner = TokenFastSimRunner(scaler, COST, c0=8, tick=0.1)
    rep = runner.run(batch)
    assert rep.n_requests == 2
    assert rep.tokens_served == 2 + 40 + 5
    # the short stream must finish well before the long one
    assert rep.mean_latency < rep.p99


def test_token_fast_runner_chunked_admission_protects_tbt():
    """A huge prompt arriving mid-stream must not blow the running
    slots' per-token budget: it is deferred, not interleaved."""
    send = np.array([0.0, 0.2])
    cl = np.full(2, 0.01)
    batch = RequestBatch.from_send(
        send, cl, slo=np.array([1.0, 10.0]),
        prompt_tokens=np.array([16, 4096]),
        decode_tokens=np.array([200, 4]),
        tbt_slo=np.array([0.012, np.inf]))
    # freeze the allocation at c=4 (single entry) so the scaler cannot
    # absorb the prompt by scaling up
    scaler = TokenSpongeScaler(COST, c_set=(4,), b_set=(1, 2, 4, 8))
    runner = TokenFastSimRunner(scaler, COST, c_set=(4,),
                                b_set=(1, 2, 4, 8), c0=4)
    rep = runner.run(batch)
    assert rep.n_requests == 2
    # prefill of 4096 tokens at c=4 takes ~0.2s >> the 12ms TBT budget;
    # chunk-bounded admission defers it so no decode token is late
    assert rep.tbt_violation_rate == 0.0


def test_token_sim_backend_exact_loop():
    batch = _token_batch(n=150, duration=20.0, seed=5)
    scaler = TokenSpongeScaler(COST)
    backend = TokenSimBackend(COST, C16, C16, c0=16)
    runner = ScenarioRunner(scaler, backend)
    runner.monitor.rate.prior_rps = 8
    rep = runner.run(batch.to_requests())
    assert rep.n_requests == len(batch)
    assert rep.tokens_served == batch.total_tokens
    assert backend.tokens_served == batch.total_tokens
    assert np.isfinite(rep.ttft_p99)
    # per-request finishes are heterogeneous inside a gang
    fins = {r.finish for r in runner.monitor.completed[:40]}
    assert len(fins) > 1


# --------------------------------------------------------------------------
# scenarios + launcher
# --------------------------------------------------------------------------
def test_llm_scenarios_registered_and_sane():
    from repro.serving.scenarios import SCENARIOS, build_scenario
    for name in ("llm-chat", "llm-mixed-len"):
        assert name in SCENARIOS
        batch, meta = build_scenario(name, duration=30, seed=2)
        assert meta["token"] and isinstance(meta["cost"], TokenCostModel)
        assert np.all(batch.prompt_tokens >= 1)
        assert np.all(batch.decode_tokens >= 1)
        assert np.all(np.isfinite(batch.tbt_slo))
        assert np.all(np.diff(batch.arrival) >= 0)


@pytest.mark.parametrize("name", ["llm-chat", "llm-mixed-len"])
def test_llm_scenarios_run_on_both_engines(name):
    from repro.serving.scenarios import run_scenario
    fast, stats = run_scenario(name, engine="fast", duration=60, seed=7)
    assert fast.n_requests > 0 and fast.tokens_served > 0
    assert stats["engine"] == "fast" and "solver" in stats
    exact, _ = run_scenario(name, engine="exact", duration=25, seed=7)
    assert exact.n_requests > 0 and exact.tokens_served > 0


def test_llm_scenario_rejects_fixed_work_policies():
    from repro.serving.scenarios import run_scenario
    with pytest.raises(ValueError):
        run_scenario("llm-chat", policy="static-8", duration=20)


def test_llm_scenarios_via_launcher():
    from repro.launch.serve import main
    main(["--scenario", "llm-chat", "--duration", "20", "--seed", "4"])
    main(["--scenario", "llm-mixed-len", "--duration", "20", "--seed",
          "4", "--engine", "exact"])


# --------------------------------------------------------------------------
# satellites: λ-estimator guard + shared decision resolution
# --------------------------------------------------------------------------
def test_rate_estimator_single_arrival_guard():
    est = RateEstimator(window_s=5.0)
    est.observe(100.0)                  # lone arrival exactly at the tick
    assert est.rate(100.0) == pytest.approx(1.0 / 5.0)
    est2 = RateEstimator(window_s=5.0)
    assert est2.rate(50.0) == 0.0       # empty window after idle gap


def test_fastpath_rate_matches_estimator_on_idle_gap_edge():
    """The two-pointer fast-path λ (now owned by the online session) and
    RateEstimator must agree on the degenerate single-arrival-after-idle
    case (equivalence contract)."""
    from repro.core.baselines import SpongePolicy
    from repro.core.scaler import SpongeScaler
    runner = FastSimRunner(SpongePolicy(SpongeScaler(PERF)), PERF,
                           c0=16)
    sess = runner.session()
    sess._arr = [100.0]                 # one processed arrival
    est = RateEstimator(window_s=runner.rate_window)
    est.observe(100.0)
    assert sess._rate(100.0) == pytest.approx(est.rate(100.0))
    assert sess._rate(100.0) < 1.0      # not a million-rps spike


def test_resolve_decision_shared_rule():
    assert resolve_decision((1, 2, 4, 8), Decision(c=3, b=5)) == (4, 5)
    assert resolve_decision((1, 2, 4, 8), Decision(c=9, b=0)) == (8, 1)
    assert resolve_decision((1, 2, 4, 8), Decision(c=4, b=2)) == (4, 2)


# --------------------------------------------------------------------------
# real kernels: model glue + TokenJaxBackend
# --------------------------------------------------------------------------
def test_pallas_prefill_route_matches_jnp_path():
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-135m-reduced")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = np.ones((2, 16), np.int32)
    lg0, _ = model.prefill(params, {"tokens": toks}, cache_len=24)
    kcfg = dataclasses.replace(cfg, use_pallas_prefill=True)
    lg1, _ = build_model(kcfg).prefill(params, {"tokens": toks},
                                       cache_len=24)
    assert np.allclose(np.asarray(lg0), np.asarray(lg1), atol=1e-4)


def test_token_jax_backend_end_to_end():
    from repro.serving.token_backend import run_token_jax_scenario
    rep, stats = run_token_jax_scenario("llm-chat", requests=8, seed=3,
                                        prompt_len=8, max_decode=3)
    assert rep.n_requests > 0
    assert stats["tokens_executed"] == rep.tokens_served > 0
    assert np.isfinite(rep.ttft_p99)
    assert stats["engine"] == "token-jax"
