"""Trip-count-weighted HLO cost analysis vs XLA ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo_analysis import collective_stats, shape_bytes
from repro.utils.hlo_cost import analyze_weighted


def test_shape_bytes():
    assert shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert shape_bytes("(f32[4,4], s32[])") == 64 + 4
    assert shape_bytes("pred[]") == 1


def _matmul_chain(x, ws, scan: bool):
    if scan:
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y
    for i in range(ws.shape[0]):
        x = x @ ws[i]
    return x


@pytest.mark.parametrize("n", [4, 12])
def test_scan_flops_match_unrolled(n):
    x = jnp.zeros((64, 256), jnp.float32)
    ws = jnp.zeros((n, 256, 256), jnp.float32)
    cs = jax.jit(lambda x, w: _matmul_chain(x, w, True)).lower(x, ws).compile()
    cu = jax.jit(lambda x, w: _matmul_chain(x, w, False)).lower(x, ws).compile()
    exp = 2 * 64 * 256 * 256 * n
    ws_ = analyze_weighted(cs.as_text())
    wu_ = analyze_weighted(cu.as_text())
    assert ws_.flops == exp
    assert wu_.flops == exp
    assert wu_.flops == float(cu.cost_analysis()["flops"])


def test_nested_scan_multipliers():
    def inner(c, w):
        y, _ = jax.lax.scan(lambda cc, _: (cc @ w, None), c, None, length=3)
        return y, None

    def outer(x, ws):
        y, _ = jax.lax.scan(inner, x, ws)
        return y

    x = jnp.zeros((32, 64), jnp.float32)
    ws = jnp.zeros((5, 64, 64), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    wc = analyze_weighted(c.as_text())
    assert wc.flops == 2 * 32 * 64 * 64 * 3 * 5


def test_bytes_within_factor_of_xla():
    x = jnp.zeros((128, 512), jnp.float32)
    ws = jnp.zeros((10, 512, 512), jnp.float32)
    c = jax.jit(lambda x, w: _matmul_chain(x, w, False)).lower(x, ws).compile()
    mine = analyze_weighted(c.as_text()).bytes_accessed
    xla = float(c.cost_analysis()["bytes accessed"])
    assert xla / 3 < mine < xla * 3


def test_collective_parse_on_text():
    fake = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), to_apply=%add
  %ag = f32[16]{0} all-gather(%ar), dimensions={0}
  ROOT %slice = f32[8]{0} slice(%ag), slice={[0:8]}
}
"""
    st = collective_stats(fake)
    assert st.bytes_by_kind["all-reduce"] == 32
    assert st.bytes_by_kind["all-gather"] == 64
    assert st.total_count == 2
